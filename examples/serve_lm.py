"""Serve a small LM with batched requests through the serving engine.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.models import registry as reg
from repro.serving import ServingEngine
from repro.serving.engine import Request


def main():
    cfg = reg.get_config("minitron-8b", n_layers=2, d_model=128, d_ff=256,
                         vocab=1024, n_heads=4, n_kv_heads=2, remat=False,
                         attn_chunk=64, loss_chunk=64)
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(bundle, params, batch_size=4, max_len=96)

    rng = np.random.default_rng(1)
    requests = [Request(prompt=list(rng.integers(1, 1024, size=5)),
                        max_tokens=12, temperature=0.0 if i % 2 else 0.8)
                for i in range(8)]
    out = engine.generate(requests)
    for i, r in enumerate(out):
        print(f"req{i}  prompt={r.prompt}\n      -> {r.output}")
    total = sum(len(r.output) for r in out)
    print(f"\nserved {len(out)} requests, {total} tokens (continuous batching, "
          "4 slots)")


if __name__ == "__main__":
    main()
