"""The paper's application (§4): Laplacian edge detection through the
approximate multiplier — core model, Pallas kernel path, and PSNR table.

Run: PYTHONPATH=src python examples/edge_detection.py
"""
import numpy as np

from repro.data import photo_like, test_image
from repro.kernels.laplacian_conv.ops import laplacian_conv
from repro.nn import conv


def ascii_render(img: np.ndarray, width: int = 48) -> str:
    h, w = img.shape
    step = max(1, w // width)
    chars = " .:-=+*#%@"
    rows = []
    for y in range(0, h, step * 2):
        row = "".join(chars[min(9, int(img[y, x]) * 10 // 256)]
                      for x in range(0, w, step))
        rows.append(row)
    return "\n".join(rows)


def main():
    img = test_image(96, 96)
    print("input image:")
    print(ascii_render(img))

    exact = np.asarray(conv.edge_detect(img, "exact"))
    approx = np.asarray(conv.edge_detect(img, "proposed"))
    print("\nexact-multiplier edge map:")
    print(ascii_render(exact))
    print("\nproposed approximate-multiplier edge map "
          f"(PSNR {conv.psnr(exact, approx):.2f} dB):")
    print(ascii_render(approx))

    # Pallas kernel path computes the same edge map bit-exactly
    px = np.asarray(img, np.int32) >> 1
    kern = np.asarray(laplacian_conv(px))
    ref = np.asarray(conv.conv2d_int(px, conv.LAPLACIAN,
                                     __import__("repro.core.multiplier",
                                                fromlist=["m"]).approx_multiply))
    assert np.array_equal(kern, ref), "Pallas kernel must match the core model"
    print("\nPallas laplacian_conv kernel output == core model: OK")

    print("\nPSNR across designs (photo-statistics image):")
    photo = photo_like(128, 128)
    ref = np.asarray(conv.edge_detect(photo, "exact"))
    for name in ("proposed", "design_du2022", "design_strollo2020",
                 "design_esposito2018"):
        p = conv.psnr(ref, np.asarray(conv.edge_detect(photo, name)))
        print(f"  {name:>22s}: {p:6.2f} dB")


if __name__ == "__main__":
    main()
