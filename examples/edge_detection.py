"""The paper's application (§4): Laplacian edge detection through the
approximate multiplier — batched substrate pipeline, Pallas kernel path,
and PSNR table.

Run: PYTHONPATH=src python examples/edge_detection.py
"""
import numpy as np

from repro.data import image_batch, photo_like, test_image
from repro.kernels.fused_conv.ops import fused_conv2d
from repro.nn import conv
from repro.nn import substrate as sub


def ascii_render(img: np.ndarray, width: int = 48) -> str:
    h, w = img.shape
    step = max(1, w // width)
    chars = " .:-=+*#%@"
    rows = []
    for y in range(0, h, step * 2):
        row = "".join(chars[min(9, int(img[y, x]) * 10 // 256)]
                      for x in range(0, w, step))
        rows.append(row)
    return "\n".join(rows)


def main():
    img = test_image(96, 96)
    print("input image:")
    print(ascii_render(img))

    exact = np.asarray(conv.edge_detect(img, "exact"))
    approx = np.asarray(conv.edge_detect(img, "proposed"))
    print("\nexact-multiplier edge map:")
    print(ascii_render(exact))
    print("\nproposed approximate-multiplier edge map "
          f"(PSNR {conv.psnr(exact, approx):.2f} dB):")
    print(ascii_render(approx))

    # batched pipeline: a whole image batch through one substrate contraction,
    # per-image bit-identical to the single-image reference path above
    imgs = image_batch(8, 96, 96)
    batched = np.asarray(conv.edge_detect_batched(imgs, "approx_bitexact:proposed"))
    singles = np.stack([np.asarray(conv.edge_detect(im, "proposed")) for im in imgs])
    assert np.array_equal(batched, singles), "batched pipeline must match the loop"
    print(f"\nbatched edge detection ({imgs.shape[0]} images) == single-image loop: OK")

    # Pallas substrate computes the same batch bit-exactly (interpret off-TPU)
    pallas = np.asarray(conv.edge_detect_batched(imgs[:2], "approx_pallas"))
    assert np.array_equal(pallas, singles[:2]), "Pallas substrate must match"
    print("approx_pallas substrate output == core model: OK")

    # fused conv kernel (im2col inside the kernel) agrees with the core model
    px = np.asarray(img, np.int32) >> 1
    kern = np.asarray(fused_conv2d(px[None], conv.LAPLACIAN, "proposed"))[0]
    ref = np.asarray(conv.conv2d_int(px, conv.LAPLACIAN,
                                     sub.get_substrate("approx_bitexact").scalar))
    assert np.array_equal(kern, ref), "fused kernel must match the core model"
    print("Pallas fused_conv kernel output == core model: OK")

    print("\nPSNR across designs (photo-statistics image, LUT substrate):")
    photo = photo_like(128, 128)
    ref = np.asarray(conv.edge_detect_batched(photo[None], "exact"))[0]
    for name in ("proposed", "design_du2022", "design_strollo2020",
                 "design_esposito2018"):
        s = sub.get_substrate("approx_lut", mult_name=name)
        out = np.asarray(conv.edge_detect_batched(photo[None], s))[0]
        print(f"  {name:>22s}: {conv.psnr(ref, out):6.2f} dB")


if __name__ == "__main__":
    main()
