"""Quickstart: the paper's approximate multiplier in 60 seconds.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import energy, lut, metrics, multiplier as m
from repro.nn import approx_dot


def main():
    # 1. multiply two signed 8-bit numbers with the paper's multiplier
    a, b = jnp.int32(-97), jnp.int32(45)
    print(f"exact   {int(a)} x {int(b)} = {int(a) * int(b)}")
    print(f"approx  {int(a)} x {int(b)} = {int(m.approx_multiply(a, b))}")

    # 2. its exhaustive error metrics (paper Table 4)
    rep = metrics.evaluate(m.approx_multiply, "proposed")
    print(f"\n{rep.row()}")
    print("paper:   ER=98.04%  NMED=0.682%  MRED=26.29%")

    # 3. hardware savings vs the best existing design (paper Table 5)
    s = energy.savings_vs("proposed", "design_du2022")
    print(f"\npower saving vs [2]: {s['power']:.1f}% (paper 14.39%), "
          f"PDP: {s['pdp']:.1f}% (paper 29.21%)")

    # 4. use it as a neural-net matmul execution mode
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    y_exact = approx_dot.approx_dot(x, w, mode="exact")
    y_approx = approx_dot.approx_dot(x, w, mode="approx_bitexact")
    rel = float(jnp.linalg.norm(y_approx - y_exact) / jnp.linalg.norm(y_exact))
    print(f"\napprox_dot relative error vs float matmul: {rel:.4f}")

    # 5. the deployment LUT artifact
    table = lut.build_lut("proposed")
    print(f"\n256x256 product LUT built; f(0,0) = {table[128, 128]} "
          "(the compensation constant fires on zero operands — true to the netlist)")


if __name__ == "__main__":
    main()
