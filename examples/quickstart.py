"""Quickstart: the paper's approximate multiplier in 60 seconds.

Run: PYTHONPATH=src python examples/quickstart.py [--plan path.json]

``--plan`` loads a per-site substrate plan (a plan JSON or a bundle dir —
e.g. one written by ``python -m repro.launch.autotune``) for the final
mixed-substrate edge-detection step; without it a small hand-written
mixed plan demonstrates the same API.
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import energy, lut, metrics, multiplier as m
from repro.nn import approx_dot


def main(plan_path=None):
    # 1. multiply two signed 8-bit numbers with the paper's multiplier
    a, b = jnp.int32(-97), jnp.int32(45)
    print(f"exact   {int(a)} x {int(b)} = {int(a) * int(b)}")
    print(f"approx  {int(a)} x {int(b)} = {int(m.approx_multiply(a, b))}")

    # 2. its exhaustive error metrics (paper Table 4)
    rep = metrics.evaluate(m.approx_multiply, "proposed")
    print(f"\n{rep.row()}")
    print("paper:   ER=98.04%  NMED=0.682%  MRED=26.29%")

    # 3. hardware savings vs the best existing design (paper Table 5)
    s = energy.savings_vs("proposed", "design_du2022")
    print(f"\npower saving vs [2]: {s['power']:.1f}% (paper 14.39%), "
          f"PDP: {s['pdp']:.1f}% (paper 29.21%)")

    # 4. use it as a neural-net matmul execution mode
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    y_exact = approx_dot.approx_dot(x, w, mode="exact")
    y_approx = approx_dot.approx_dot(x, w, mode="approx_bitexact")
    rel = float(jnp.linalg.norm(y_approx - y_exact) / jnp.linalg.norm(y_exact))
    print(f"\napprox_dot relative error vs float matmul: {rel:.4f}")

    # 5. the deployment LUT artifact
    table = lut.build_lut("proposed")
    print(f"\n256x256 product LUT built; f(0,0) = {table[128, 128]} "
          "(the compensation constant fires on zero operands — true to the netlist)")

    # 6. per-site substrate plans: mixed-substrate edge detection
    import pathlib

    from repro.data import test_image
    from repro.nn import conv
    from repro.nn.plan import SubstratePlan, load_plan

    if plan_path:
        p = pathlib.Path(plan_path)
        if p.is_dir():
            from repro.checkpoint import load_plan_bundle
            plan, _, _ = load_plan_bundle(str(p))
        else:
            plan = load_plan(str(p))
    else:  # cheaper center tap, full-width smoothing ring
        plan = SubstratePlan(
            default="approx_bitexact:proposed@8",
            rules=(("conv.edge.center", "approx_bitexact:proposed@6"),))
    img = test_image(96, 96)[None]
    ref = np.asarray(conv.edge_detect_batched(img, "exact"))
    planned = np.asarray(conv.edge_detect_planned(img, plan))
    print(f"\nplanned edge detection under {plan.label}: "
          f"PSNR={conv.psnr(ref, planned):.2f} dB vs exact")
    for pattern, spec in plan.rules:
        print(f"  {pattern} -> {spec}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="substrate plan JSON or bundle dir for step 6")
    main(plan_path=ap.parse_args().plan)
