"""Serve edge-detection requests through the micro-batching service.

Queues a stream of mixed-shape images into an ``EdgeDetectService`` running
on a chosen product substrate, verifies every served edge map is
bit-identical to the direct batched pipeline, and prints the telemetry
table (throughput, latency percentiles, batch occupancy).

Run:  PYTHONPATH=src python examples/serve_edge.py [--smoke]
      [--substrate approx_lut:design_du2022] [--requests 24]
"""
import argparse

import numpy as np

from repro.data import mixed_shape_batch
from repro.nn import conv
from repro.serving import EdgeDetectService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--substrate", default="approx_bitexact",
                    help="ProductSubstrate spec (e.g. approx_pallas, "
                         "approx_lut:design_du2022)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (few small images)")
    args = ap.parse_args()

    if args.smoke:
        args.requests = 6
        imgs = mixed_shape_batch(args.requests,
                                 shapes=((16, 16), (24, 31), (32, 32)))
    else:
        imgs = mixed_shape_batch(args.requests, noise=2.0)

    svc = EdgeDetectService(args.substrate, max_batch_size=args.max_batch,
                            max_wait_s=args.max_wait_ms * 1e-3)
    print(f"serving {len(imgs)} mixed-shape images on "
          f"substrate={svc.spec!r} (max_batch={args.max_batch}, "
          f"max_wait={args.max_wait_ms}ms)")

    outs = svc.detect(imgs)
    svc.close()

    # every served map must be bit-identical to the direct batched pipeline
    for im, out in zip(imgs, outs):
        ref = np.asarray(conv.edge_detect_batched(im[None], svc.substrate))[0]
        assert out.shape == im.shape and np.array_equal(out, ref), \
            f"service output diverged from edge_detect_batched at {im.shape}"
    shapes = sorted({im.shape for im in imgs})
    print(f"served == direct edge_detect_batched (bit-identical) across "
          f"{len(shapes)} shapes: OK")
    print(f"compiled bucket shapes: {list(svc.compiled_shapes)}")
    print()
    print(svc.metrics.format_table())


if __name__ == "__main__":
    main()
