"""Serve edge-detection requests through the micro-batching service.

Queues a stream of mixed-shape images into an ``EdgeDetectService`` running
on a chosen product substrate, verifies every served edge map is
bit-identical to the direct batched pipeline, and prints the telemetry
table (throughput, latency percentiles, batch occupancy).

``--metrics-out`` dumps the combined metrics registry (serving counters +
per-contraction substrate meters; ``.prom``/``.txt`` → Prometheus text,
else JSON) and ``--trace-out`` records the serving spans (queue wait, pad,
compile, execute, crop) as a Chrome/Perfetto trace — CI smoke-validates
both artifacts. See ``docs/observability.md``.

``--workers N`` serves through N concurrent batcher workers (batch k+1
dispatches while batch k runs — the per-worker ``serving_worker_*`` metric
families and the ``serving_inflight_batches_peak`` gauge land in the same
dump); outputs stay bit-identical at every worker count. ``--sharded``
partitions each served contraction across the visible device mesh through
``shard_map`` (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
on a CPU host).

Run:  PYTHONPATH=src python examples/serve_edge.py [--smoke]
      [--substrate approx_lut:design_du2022] [--requests 24]
      [--workers 4] [--sharded]
      [--metrics-out serve.json] [--trace-out trace.json]
"""
import argparse

import numpy as np

from repro.data import mixed_shape_batch
from repro.nn import conv
from repro.obs import (ContractionMeter, MetricsRegistry, Tracer,
                       telemetry_scope, tracing_scope, write_chrome_trace,
                       write_metrics)
from repro.serving import EdgeDetectService
from repro.serving.metrics import ServingMetrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--substrate", default="approx_bitexact",
                    help="ProductSubstrate spec (e.g. approx_pallas, "
                         "approx_lut:design_du2022)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--workers", type=int, default=1,
                    help="batcher worker threads (overlap dispatch of "
                         "batch k+1 with batch k's device compute)")
    ap.add_argument("--sharded", action="store_true",
                    help="partition served contractions across the visible "
                         "device mesh via shard_map")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI (few small images)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the combined metrics registry (.prom/.txt → "
                         "Prometheus text, else JSON)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "serving spans")
    args = ap.parse_args()

    if args.smoke:
        args.requests = 6
        imgs = mixed_shape_batch(args.requests,
                                 shapes=((16, 16), (24, 31), (32, 32)))
    else:
        imgs = mixed_shape_batch(args.requests, noise=2.0)

    # one shared registry: serving counters + substrate meters, one dump
    registry = MetricsRegistry()
    meter = ContractionMeter(registry)
    tracer = Tracer() if args.trace_out else None
    partitioning = None
    if args.sharded:
        from repro.launch.mesh import (contraction_partitioning,
                                       make_debug_mesh)
        partitioning = contraction_partitioning(make_debug_mesh())
    with tracing_scope(tracer), telemetry_scope(meter):
        svc = EdgeDetectService(args.substrate,
                                max_batch_size=args.max_batch,
                                max_wait_s=args.max_wait_ms * 1e-3,
                                n_workers=args.workers,
                                partitioning=partitioning,
                                metrics=ServingMetrics(registry=registry))
        print(f"serving {len(imgs)} mixed-shape images on "
              f"substrate={svc.spec!r} (max_batch={args.max_batch}, "
              f"max_wait={args.max_wait_ms}ms, workers={args.workers}"
              f"{', sharded' if args.sharded else ''})")

        outs = svc.detect(imgs)
        svc.close()

    # every served map must be bit-identical to the direct batched pipeline
    for im, out in zip(imgs, outs):
        ref = np.asarray(conv.edge_detect_batched(im[None], svc.substrate))[0]
        assert out.shape == im.shape and np.array_equal(out, ref), \
            f"service output diverged from edge_detect_batched at {im.shape}"
    shapes = sorted({im.shape for im in imgs})
    print(f"served == direct edge_detect_batched (bit-identical) across "
          f"{len(shapes)} shapes: OK")
    print(f"compiled bucket shapes: {list(svc.compiled_shapes)}")
    print()
    print(svc.metrics.format_table())
    summary = meter.summary()
    if summary:
        print()
        for spec, row in sorted(summary.items()):
            print(f"meter      {spec}: {row['contractions']} contractions, "
                  f"{row['macs']} MACs, "
                  f"{row['energy_pdp_fj'] / 1e6:.2f} nJ est.")
    if args.metrics_out:
        p = write_metrics(registry, args.metrics_out,
                          extra={"substrate_meter": summary})
        print(f"metrics -> {p}")
    if args.trace_out:
        p = write_chrome_trace(tracer, args.trace_out)
        print(f"trace -> {p} ({len(tracer.events())} events)")


if __name__ == "__main__":
    main()
