"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
paper's approximate multiplier as the matmul execution mode, with
checkpointing + resume.

Default is a fast reduced run; pass --full for the ~100M/300-step version
(slow on 1 CPU).

Run: PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse

import jax

from repro.data import SyntheticLMStream
from repro.models import registry as reg
from repro.optim import adamw, warmup_cosine
from repro.train import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (slow on CPU)")
    ap.add_argument("--dot-mode", default="exact",
                    choices=["exact", "int8", "approx_stat", "approx_bitexact"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.full:
        over = dict(n_layers=8, d_model=768, d_ff=2048, vocab=32768,
                    n_heads=12, n_kv_heads=4, attn_chunk=256, loss_chunk=256)
        steps, batch, seq = 300, 8, 256
    else:
        over = dict(n_layers=2, d_model=128, d_ff=256, vocab=1024,
                    n_heads=4, n_kv_heads=2, attn_chunk=64, loss_chunk=64,
                    remat=False)
        steps, batch, seq = 60, 8, 64

    cfg = reg.get_config("minitron-8b", dot_mode=args.dot_mode, **over)
    bundle = reg._BUILDERS[cfg.family](cfg)

    loop = TrainLoop(
        bundle.loss_fn, adamw(),
        TrainLoopConfig(total_steps=steps, ckpt_every=max(10, steps // 5),
                        ckpt_dir=args.ckpt_dir, lr=3e-3),
        lr_schedule=warmup_cosine(3e-3, steps // 10, steps),
    )
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=batch, seq_len=seq, seed=0)
    params, opt_state, start = loop.init_or_restore(
        lambda: bundle.init_params(jax.random.PRNGKey(0)))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"training {n_params:,} params from step {start} "
          f"(dot_mode={cfg.dot_mode}); checkpoints -> {args.ckpt_dir}")
    loop.run(params, opt_state, stream, start,
             on_step=lambda s, l: (s % 10 == 0) and print(
                 f"  step {s:4d}  loss {l:.4f}", flush=True))
    losses = loop.metrics["losses"]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(resume with the same command; delete {args.ckpt_dir} to restart)")


if __name__ == "__main__":
    main()
