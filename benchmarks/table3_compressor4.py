"""Paper Table 3: proposed A+B+C+D+1 compressor truth table + statistics."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors as comp


def run() -> list:
    c = comp.PROPOSED4
    print("\n== Table 3: proposed A+B+C+D+1 (reconstruction) ==")
    print("A B C D | exact approx ED   P(combo)")
    probs = c.input_probs()
    for idx in range(16):
        bits = [(idx >> k) & 1 for k in (3, 2, 1, 0)]
        print(f"{bits[0]} {bits[1]} {bits[2]} {bits[3]} |   {c.exact[idx]}     "
              f"{c.values[idx]}    {c.errors[idx]:+d}   {probs[idx]:.4f}")
    pe, em = c.error_probability(), c.mean_error()
    print(f"P_E = {pe:.4f} (58/256), E_mean = {em:+.4f} (+7/256)")
    assert abs(pe - 58 / 256) < 1e-12 and abs(em - 7 / 256) < 1e-12

    idx = jnp.asarray(np.random.default_rng(0).integers(0, 16, 1 << 16))
    f = jax.jit(c.apply_packed)
    f(idx).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(idx).block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    return [("table3/proposed4", us, f"PE={pe:.4f};Emean={em:+.4f}")]
