"""Recovered-quality benchmark for approximation-aware training (QAT).

For a grid of wirings × operand widths, measures quality on the two paper
workloads **before** and **after** a short QAT fine-tune under that wiring's
own error (:mod:`repro.train.qat`):

* **edge** — PSNR of the planned Laplacian edge maps vs the exact
  multiplier (paper Fig. 9 metric). Pre = the untrained integer pipeline
  (`edge_detect_planned`), post = the QAT edge model after
  :func:`repro.train.qat.finetune_edge`.
* **lm** — eval loss of a reduced LM on a fixed synthetic batch, running
  its denses on the approximate substrate. Pre = exact-pretrained params
  evaluated on the approximate forward, post = after a short QAT
  fine-tune (stat forward for speed; eval is always bit-exact).

Each row carries the wiring's per-MAC PDP (unit-gate model, Table 5
pricing) and the workload's metered plan energy, so the headline
``recovered_points`` can be read directly: operating points *cheaper* than
uniform ``proposed@8`` whose post-QAT edge PSNR matches or beats the
uniform ``proposed@8`` pipeline *without* QAT — approximate training
buying back the quality that a cheaper multiplier gives up.

Writes ``BENCH_qat.json`` at the repo root. Standalone:
``python -m benchmarks.qat_recovery [--dry-run] [--json PATH]``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.data import image_batch
from repro.launch import autotune
from repro.nn import conv
from repro.nn import plan as plan_mod
from repro.obs.meter import pdp_per_mac_fj
from repro.train import qat

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = _REPO_ROOT / "BENCH_qat.json"

REFERENCE = ("proposed", 8)                # the paper's headline multiplier
WIRINGS = ("proposed", "design_du2022", "design_strollo2020")
WIDTHS = (6, 8)

# reduced LM (same shape as the launcher smoke runs)
LM_ARCH = "minitron-8b"
LM_OVERRIDES = dict(n_layers=2, d_model=64, d_ff=128, vocab=128,
                    n_heads=4, n_kv_heads=2)


def _spec(wiring: str, width: int) -> str:
    return f"approx_bitexact:{wiring}@{width}"


def _mac_fj(spec: str) -> float:
    from repro.nn import substrate as psub

    return pdp_per_mac_fj(psub.get_substrate(spec).meta.mult_key)


def _edge_rows(imgs, *, steps: int, lr: float = 0.05):
    """One row per wiring×width: pre/post PSNR + energy figures."""
    rows = []
    for wiring in WIRINGS:
        for width in WIDTHS:
            plan = plan_mod.SubstratePlan.uniform(_spec(wiring, width))
            site_macs = autotune.measure_site_macs(
                lambda p: np.asarray(conv.edge_detect_planned(imgs, p)), plan)
            t0 = time.perf_counter()
            fin = qat.finetune_edge(imgs, plan, steps=steps, lr=lr)
            us = (time.perf_counter() - t0) * 1e6
            rows.append({
                "wiring": wiring, "width": width,
                "spec": _spec(wiring, width),
                "psnr_pre_db": fin["psnr_pre"],
                "psnr_post_db": fin["psnr_post"],
                "pdp_per_mac_fj": _mac_fj(_spec(wiring, width)),
                "plan_pdp_fj": autotune.plan_pdp_fj(site_macs, plan),
                "qat_steps": steps, "finetune_us": us,
            })
    return rows


def _lm_rows(*, pretrain_steps: int, qat_steps: int, widths=(6, 8),
             wirings=("proposed",)):
    """Reduced-LM eval loss on the approximate substrate, pre vs post QAT."""
    import jax
    import jax.numpy as jnp

    from repro.data import SyntheticLMStream
    from repro.models import registry as reg
    from repro.optim import adamw

    opt = adamw()
    stream = SyntheticLMStream(vocab=LM_OVERRIDES["vocab"], batch=4,
                               seq_len=32, seed=0)

    # exact pretrain → the params every wiring starts its recovery from
    exact_bundle = reg.get_bundle(LM_ARCH, dot_plan="exact", **LM_OVERRIDES)
    params = exact_bundle.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    step_exact = jax.jit(lambda p, s, b: _sgd_step(exact_bundle.loss_fn,
                                                   opt, p, s, b))
    stream.seek(0)
    for _ in range(pretrain_steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
        _, params, state = step_exact(params, state, batch)
    eval_batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
    exact_loss = float(exact_bundle.loss_fn(params, eval_batch))

    rows = []
    for wiring in wirings:
        for width in widths:
            spec = _spec(wiring, width)
            plan = plan_mod.SubstratePlan.uniform(spec)
            bundle = reg.get_bundle(LM_ARCH, dot_plan=plan, **LM_OVERRIDES)
            pre = float(bundle.loss_fn(params, eval_batch))

            policy = qat.QATPolicy(forward="stat")

            def qat_loss(p, b, _f=bundle.loss_fn, _pol=policy):
                with qat.qat_scope(_pol):
                    return _f(p, b)

            p2, s2 = params, opt.init(params)
            step_qat = jax.jit(lambda p, s, b: _sgd_step(qat_loss, opt,
                                                         p, s, b))
            stream.seek(pretrain_steps + 1)
            for _ in range(qat_steps):
                batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
                _, p2, s2 = step_qat(p2, s2, batch)
            post = float(bundle.loss_fn(p2, eval_batch))
            rows.append({
                "wiring": wiring, "width": width, "spec": spec,
                "loss_exact": exact_loss, "loss_pre": pre, "loss_post": post,
                "pdp_per_mac_fj": _mac_fj(spec),
                "pretrain_steps": pretrain_steps, "qat_steps": qat_steps,
            })
    return rows


def _sgd_step(loss_fn, opt, params, state, batch):
    import jax

    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    new_params, new_state = opt.update(grads, state, params, lr=1e-3)
    return loss, new_params, new_state


def _recovered_points(edge_rows):
    """Cheaper-than-reference rows whose *post*-QAT PSNR ≥ reference *pre*."""
    ref = next(r for r in edge_rows
               if (r["wiring"], r["width"]) == REFERENCE)
    return [
        {"spec": r["spec"], "pdp_per_mac_fj": r["pdp_per_mac_fj"],
         "psnr_post_db": r["psnr_post_db"],
         "reference_spec": ref["spec"],
         "reference_pdp_per_mac_fj": ref["pdp_per_mac_fj"],
         "reference_psnr_pre_db": ref["psnr_pre_db"],
         "energy_saved_frac": 1 - r["pdp_per_mac_fj"] / ref["pdp_per_mac_fj"]}
        for r in edge_rows
        if r["pdp_per_mac_fj"] < ref["pdp_per_mac_fj"]
        and r["psnr_post_db"] >= ref["psnr_pre_db"]
    ]


def run(dry_run: bool = False, json_path=DEFAULT_JSON) -> list:
    """Harness entry point; returns ``(name, us, derived)`` CSV rows."""
    if dry_run:
        imgs = image_batch(2, 24, 24)
        edge = _edge_rows(imgs, steps=4)
        lm = _lm_rows(pretrain_steps=3, qat_steps=3, widths=(8,))
        json_path = None
    else:
        imgs = image_batch(4, 48, 48)
        edge = _edge_rows(imgs, steps=120)
        lm = _lm_rows(pretrain_steps=40, qat_steps=25)

    print(f"\n== QAT recovery (edge: {imgs.shape[0]}x{imgs.shape[1]}"
          f"x{imgs.shape[2]}) ==")
    print(f"{'spec':>34s} {'pre_db':>7s} {'post_db':>8s} {'fJ/MAC':>8s}")
    for r in edge:
        print(f"{r['spec']:>34s} {r['psnr_pre_db']:7.2f} "
              f"{r['psnr_post_db']:8.2f} {r['pdp_per_mac_fj']:8.1f}")
    print(f"{'lm spec':>34s} {'pre':>7s} {'post':>8s} {'exact':>8s}")
    for r in lm:
        print(f"{r['spec']:>34s} {r['loss_pre']:7.3f} "
              f"{r['loss_post']:8.3f} {r['loss_exact']:8.3f}")

    recovered = _recovered_points(edge)
    for p in recovered:
        print(f"[qat] recovered point: {p['spec']} "
              f"({p['pdp_per_mac_fj']:.1f} fJ/MAC, "
              f"{100 * p['energy_saved_frac']:.0f}% cheaper) post-QAT "
              f"{p['psnr_post_db']:.2f} dB >= {p['reference_spec']} pre-QAT "
              f"{p['reference_psnr_pre_db']:.2f} dB")
    if not dry_run and not recovered:
        raise AssertionError(
            "no recovered operating point: QAT failed to match the "
            "reference quality at any cheaper wiring/width")

    rows = []
    for r in edge:
        rows.append((f"qat/edge/{r['wiring']}@{r['width']}",
                     r["finetune_us"],
                     f"pre={r['psnr_pre_db']:.2f}dB,"
                     f"post={r['psnr_post_db']:.2f}dB"))
    for r in lm:
        rows.append((f"qat/lm/{r['wiring']}@{r['width']}", 0.0,
                     f"pre={r['loss_pre']:.3f},post={r['loss_post']:.3f}"))

    if json_path:
        payload = {
            "reference": _spec(*REFERENCE),
            "wirings": list(WIRINGS), "widths": list(WIDTHS),
            "edge": edge, "lm": lm,
            "recovered_points": recovered,
            "lm_arch": LM_ARCH, "lm_overrides": LM_OVERRIDES,
        }
        pathlib.Path(json_path).write_text(
            json.dumps(payload, indent=1) + "\n")
        print(f"[bench qat] wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny grid + steps, no JSON artifact (CI smoke)")
    ap.add_argument("--json", default=str(DEFAULT_JSON), dest="json_path")
    args = ap.parse_args()
    run(dry_run=args.dry_run,
        json_path=None if args.dry_run else args.json_path)
