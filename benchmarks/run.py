"""Benchmark harness — one module per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV at the end (plus human-readable
tables as it goes). ``python -m benchmarks.run [--only table4]``.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig9_edge,
    fig10_tradeoff,
    kernelbench,
    table2_compressors,
    table3_compressor4,
    table4_errors,
    table5_hardware,
)

MODULES = {
    "table2": table2_compressors,
    "table3": table3_compressor4,
    "table4": table4_errors,
    "table5": table5_hardware,
    "fig9": fig9_edge,
    "fig10": fig10_tradeoff,
    "kernel": kernelbench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args()

    rows = []
    failed = False
    for name, mod in MODULES.items():
        if args.only and name != args.only:
            continue
        try:
            rows.extend(mod.run())
        except Exception:  # noqa: BLE001
            failed = True
            print(f"[bench {name}] FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
