"""Benchmark harness — one module per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV at the end (plus human-readable
tables as it goes). ``python -m benchmarks.run [--only table4]
[--substrates exact,approx_pallas] [--sharded]`` — the substrate-sweep
benches (fig9, kernel) default to every substrate registered in
``repro.nn.substrate``; ``--sharded`` adds the kernel bench's
``dot_general`` + ``Partitioning`` rows (sweeps sharded contractions over a
mesh of every visible device — the TPU-native run's sharded sweep).

Machine-readable artifacts: the ``kernel`` bench writes
``BENCH_kernels.json``, the ``serve_edge`` bench writes
``BENCH_serving.json`` (throughput/latency records + the substrate-meter
energy rollup), and the ``autotune`` bench writes ``BENCH_autotune.json``
(plan-vs-uniform PDP/PSNR table; ``--plan`` evaluates a saved plan/bundle
instead of searching), and the ``qat`` bench writes ``BENCH_qat.json``
(pre/post-QAT quality across wirings × widths + recovered operating
points) at the repo root, so one ``python -m benchmarks.run`` produces the
full perf trajectory. Trace files are opt-in via each bench's
standalone ``--trace`` flag.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    autotune_plan,
    edge_serving,
    fig9_edge,
    fig10_tradeoff,
    kernelbench,
    qat_recovery,
    table2_compressors,
    table3_compressor4,
    table4_errors,
    table5_hardware,
)

MODULES = {
    "table2": table2_compressors,
    "table3": table3_compressor4,
    "table4": table4_errors,
    "table5": table5_hardware,
    "fig9": fig9_edge,
    "fig10": fig10_tradeoff,
    "kernel": kernelbench,
    "serve_edge": edge_serving,
    "autotune": autotune_plan,
    "qat": qat_recovery,
}


# benches that sweep the ProductSubstrate registry (accept substrates=[...])
_SUBSTRATE_SWEEPS = ("fig9", "kernel", "serve_edge")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(MODULES))
    ap.add_argument("--substrates", default=None,
                    help="CSV of substrate specs for the sweep benches "
                         "(default: all registered)")
    ap.add_argument("--sharded", action="store_true",
                    help="add the kernel bench's sharded dot_general rows "
                         "(Partitioning over a mesh of all visible devices)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="substrate-plan JSON or bundle dir for the "
                         "autotune bench (default: greedy search)")
    args = ap.parse_args()
    substrates = args.substrates.split(",") if args.substrates else None

    rows = []
    failed = False
    for name, mod in MODULES.items():
        if args.only and name != args.only:
            continue
        kwargs = {"substrates": substrates} if name in _SUBSTRATE_SWEEPS else {}
        if name == "kernel":
            kwargs["sharded"] = args.sharded
        if name == "autotune":
            kwargs["plan"] = args.plan
        try:
            rows.extend(mod.run(**kwargs))
        except Exception:  # noqa: BLE001
            failed = True
            print(f"[bench {name}] FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
