"""Paper Table 2: A+B+C+1 compressor truth-table statistics (P_E, E_mean)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors as comp


def run() -> list:
    rows = []
    print("\n== Table 2: sign-focused A+B+C+1 compressors ==")
    print(f"{'design':>22s} {'P_E':>8s} {'paper':>8s} {'E_mean':>8s} {'paper':>8s}")
    for name, c in comp.ALL_3INPUT.items():
        pe, em = c.error_probability(), c.mean_error()
        ppe, pem = comp.PAPER_TABLE2_STATS.get(name, (0.0, 0.0)) if \
            name != "exact3" else (0.0, 0.0)
        print(f"{name:>22s} {pe:8.4f} {ppe:8.4f} {em:+8.4f} {pem:+8.4f}")
        assert abs(pe - ppe) < 1e-9 and abs(em - pem) < 1e-9, name

        # throughput of the vectorized compressor evaluation
        idx = jnp.asarray(np.random.default_rng(0).integers(0, 8, 1 << 16))
        f = jax.jit(c.apply_packed)
        f(idx).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            f(idx).block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6
        rows.append((f"table2/{name}", us, f"PE={pe:.4f};Emean={em:+.4f}"))
    return rows
