"""Kernel micro-benchmarks (beyond paper): product-substrate sweep.

Times the integer contraction (``dot_int``) of every substrate registered
in ``repro.nn.substrate`` — no hand-maintained mode list — on CPU. Pallas
substrates run in interpret mode here (wall-clock kernel numbers only mean
something on real TPU); the XLA modes give the CPU-comparable throughput
picture and the relative cost of bit-exact emulation.

Beyond the substrate sweep, this bench times the PR-6 kernel pipeline:

* vectorized k-slab (``k_chunk=8``) vs the scalar fori baseline
  (``k_chunk=1``) for both the generated closed-form matmul and the
  flat-LUT gather matmul;
* the fused conv kernel (in-kernel im2col) vs the host-side
  im2col + ``dot_general`` reference path.

Every row also lands in a machine-readable ``BENCH_kernels.json``
(wall-clock µs after warmup, ``block_until_ready``-fenced, keyed by
kernel × wiring × width) next to the repo root so runs are diffable.

``sharded=True`` (``benchmarks.run --only kernel --sharded``) adds a
``dot_general`` + ``Partitioning`` sweep over a debug mesh of every visible
device (data-parallel M, reduce-scattered K) — the TPU-native benchmark run
uses it to sweep sharded contractions; under
``--xla_force_host_platform_device_count=N`` it exercises the same lowering
on CPU.

Standalone: ``python -m benchmarks.kernelbench [--dry-run] [--sharded]
[--substrates a,b] [--json PATH]`` — ``--dry-run`` shrinks every shape so
the whole bench (interpret mode included) finishes in seconds; CI uses it
as a smoke gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import substrate as sub

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = _REPO_ROOT / "BENCH_kernels.json"


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _sharded_rows(specs, a8, b8, macs, records) -> list:
    """dot_general + Partitioning sweep over a debug mesh of all devices."""
    from repro.launch import mesh as mesh_lib

    rows = []
    mesh = mesh_lib.make_debug_mesh()
    part = mesh_lib.contraction_partitioning(mesh)
    print(f"\n== kernel bench: sharded dot_general "
          f"(mesh {dict(mesh.shape)}, m_axis={part.m_axis}, "
          f"k_axis={part.k_axis}) ==")
    for spec in specs:
        s = sub.get_substrate(spec)
        cspec = sub.ContractionSpec(partitioning=part)
        f = jax.jit(lambda a, b, _s=s, _c=cspec: _s.dot_general(a, b, _c))
        us = _time(f, a8, b8)
        gmacs = macs / us / 1e3
        print(f"{spec:>16s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s) [sharded]")
        rows.append((f"kernel/sharded_{s.meta.label}", us,
                     f"gmacs={gmacs:.2f};devices={mesh.size}"))
        records.append({"section": "sharded", "kernel": "dot_general",
                        "spec": spec, "us": round(us, 1),
                        "gmacs": round(gmacs, 3), "devices": mesh.size})
    return rows


def _kslab_rows(rng, records, dry_run) -> list:
    """Vectorized k-slab (k_chunk=8) vs the fori baseline (k_chunk=1)."""
    from repro.core import lut as lut_lib
    from repro.kernels.approx_matmul.ops import closed_form_matmul
    from repro.kernels.lut_matmul.ops import lut_matmul

    m = k = n = 32 if dry_run else 128
    blk = dict(block_m=m, block_n=n, block_k=k)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    macs = m * k * n
    rows = []
    print(f"\n== kernel bench: k-slab vectorization ({m}x{k}x{n}, "
          f"k_chunk=8 vs fori k_chunk=1) ==")
    for kernel, fn in (
        ("closed_form_matmul",
         lambda kc: closed_form_matmul(a, b, "proposed", k_chunk=kc, **blk)),
        ("lut_matmul",
         lambda kc, _t=jnp.asarray(lut_lib.flat_lut("proposed"), jnp.int32):
         lut_matmul(a, b, _t, k_chunk=kc, **blk)),
    ):
        base = None
        for kc in (1, 8):
            us = _time(fn, kc)
            gmacs = macs / us / 1e3
            tag = "fori" if kc == 1 else "vectorized"
            speedup = (base / us) if base else 1.0
            if kc == 1:
                base = us
            print(f"{kernel:>20s} k_chunk={kc} ({tag:>10s}): {us:10.0f} us  "
                  f"({gmacs:6.2f} GMAC/s, {speedup:4.2f}x vs fori)")
            rows.append((f"kernel/kslab_{kernel}_kc{kc}", us,
                         f"gmacs={gmacs:.2f};speedup={speedup:.2f}x"))
            records.append({"section": "kslab", "kernel": kernel,
                            "wiring": "proposed", "width": 8, "k_chunk": kc,
                            "shape": [m, k, n], "us": round(us, 1),
                            "gmacs": round(gmacs, 3),
                            "speedup_vs_fori": round(speedup, 3)})
    return rows


def _fused_conv_rows(rng, records, dry_run) -> list:
    """Fused conv kernel (in-kernel im2col) vs host-side im2col path."""
    from repro.nn import conv

    b, h, w = (2, 32, 32) if dry_run else (4, 128, 128)
    imgs = jnp.asarray(rng.integers(-128, 128, (b, h, w)), jnp.int32)
    s = sub.get_substrate("approx_pallas:proposed")
    rows = []
    print(f"\n== kernel bench: fused conv vs im2col ({b}x{h}x{w}, "
          f"3x3 Laplacian) ==")
    base = None
    for fused, tag in ((False, "im2col"), (True, "fused")):
        f = jax.jit(lambda x, _f=fused: conv.conv2d_batched(
            x, conv.LAPLACIAN, s, fused=_f))
        us = _time(f, imgs)
        speedup = (base / us) if base else 1.0
        if not fused:
            base = us
        print(f"{tag:>10s}: {us:10.0f} us  ({speedup:4.2f}x vs im2col)")
        rows.append((f"kernel/conv_{tag}", us,
                     f"imgs={b}x{h}x{w};speedup={speedup:.2f}x"))
        records.append({"section": "fused_conv", "kernel": f"conv_{tag}",
                        "wiring": "proposed", "width": 8,
                        "shape": [b, h, w], "us": round(us, 1),
                        "speedup_vs_im2col": round(speedup, 3)})
    return rows


def run(substrates=None, sharded=False, dry_run=False,
        json_path=DEFAULT_JSON, trace_path=None) -> list:
    from repro.obs import Tracer, tracing_scope, write_chrome_trace

    tracer = Tracer() if trace_path else None
    with tracing_scope(tracer):
        rows = _run_benches(substrates, sharded, dry_run, json_path)
    if trace_path:
        p = write_chrome_trace(tracer, trace_path)
        print(f"wrote {len(tracer.events())} trace events to {p}")
    return rows


def _run_benches(substrates, sharded, dry_run, json_path) -> list:
    rows = []
    records: list[dict] = []
    rng = np.random.default_rng(0)
    m, k, n = (32, 64, 32) if dry_run else (256, 512, 256)
    a8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    specs = list(substrates) if substrates else sub.list_substrates()
    print(f"\n== kernel bench: int8 matmul substrates ({m}x{k}x{n}, CPU) ==")
    macs = m * k * n
    for spec in specs:
        s = sub.get_substrate(spec)
        f = jax.jit(lambda a, b, _s=s: _s.dot_int(a, b))
        us = _time(f, a8, b8)
        gmacs = macs / us / 1e3
        note = " [interpret]" if s.meta.preferred_backend == "tpu" \
            and jax.default_backend() != "tpu" else ""
        print(f"{spec:>16s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s){note}")
        rows.append((f"kernel/matmul_{s.meta.label}", us, f"gmacs={gmacs:.2f}"))
        records.append({"section": "substrates", "kernel": "dot_int",
                        "spec": spec, "shape": [m, k, n], "us": round(us, 1),
                        "gmacs": round(gmacs, 3),
                        "cost_hint": s.meta.cost_hint})

    if sharded:
        rows.extend(_sharded_rows(specs, a8, b8, macs, records))

    # pallas × wiring × width sweep: every CSP wiring rides the generated
    # closed-form kernel (cost_hint "vpu"); only product models without CSP
    # structure ("exact") fall back to the flat-table gather ("gather").
    pm = pk = pn = 32 if dry_run else 128
    pa = jnp.asarray(rng.integers(-128, 128, (pm, pk)), jnp.int8)
    pb = jnp.asarray(rng.integers(-128, 128, (pk, pn)), jnp.int8)
    pmacs = pm * pk * pn
    print(f"\n== kernel bench: pallas wiring x width sweep ({pm}x{pk}x{pn}) ==")
    for wiring in ("proposed", "csp_axc1", "design_strollo2020"):
        for width in (4, 8):
            spec = f"approx_pallas:{wiring}@{width}"
            s = sub.get_substrate(spec)
            f = jax.jit(lambda a, b, _s=s: _s.dot_int(a, b))
            us = _time(f, pa, pb)
            gmacs = pmacs / us / 1e3
            note = " [interpret]" if jax.default_backend() != "tpu" else ""
            print(f"{spec:>34s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s) "
                  f"[{s.meta.cost_hint}]{note}")
            rows.append((f"kernel/pallas_{wiring}@{width}", us,
                         f"gmacs={gmacs:.2f};cost={s.meta.cost_hint}"))
            records.append({"section": "pallas_sweep", "kernel": "dot_int",
                            "wiring": wiring, "width": width,
                            "shape": [pm, pk, pn], "us": round(us, 1),
                            "gmacs": round(gmacs, 3),
                            "cost_hint": s.meta.cost_hint})

    rows.extend(_kslab_rows(rng, records, dry_run))
    rows.extend(_fused_conv_rows(rng, records, dry_run))

    from repro.kernels.approx_mul.ops import approx_mul
    side = 64 if dry_run else 512
    x = jnp.asarray(rng.integers(-128, 128, (side, side)), jnp.int32)
    y = jnp.asarray(rng.integers(-128, 128, (side, side)), jnp.int32)
    us = _time(approx_mul, x, y)
    rows.append(("kernel/approx_mul_pallas_interp", us, f"{side}x{side}"))
    records.append({"section": "elementwise", "kernel": "approx_mul",
                    "wiring": "proposed", "width": 8, "shape": [side, side],
                    "us": round(us, 1)})
    print(f"pallas approx_mul (interpret): {us:.0f} us")

    if json_path:
        payload = {
            "bench": "kernelbench",
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "dry_run": bool(dry_run),
            "timing": "mean wall-clock us over 5 iters, "
                      "1 warmup + block_until_ready",
            "records": records,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1)
                                           + "\n")
        print(f"\nwrote {len(records)} records to {json_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes — seconds-fast smoke run (CI gate)")
    ap.add_argument("--sharded", action="store_true",
                    help="add sharded dot_general rows (debug mesh)")
    ap.add_argument("--substrates", default=None,
                    help="CSV of substrate specs (default: all registered)")
    ap.add_argument("--json", default=str(DEFAULT_JSON), dest="json_path",
                    help="output path for BENCH_kernels.json ('' disables)")
    ap.add_argument("--trace", default=None, dest="trace_path",
                    help="write a Chrome/Perfetto trace of the kernel "
                         "dispatch spans")
    args = ap.parse_args()
    substrates = args.substrates.split(",") if args.substrates else None
    rows = run(substrates=substrates, sharded=args.sharded,
               dry_run=args.dry_run, json_path=args.json_path or None,
               trace_path=args.trace_path)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
