"""Kernel micro-benchmarks (beyond paper): product-substrate sweep.

Times the integer contraction (``dot_int``) of every substrate registered
in ``repro.nn.substrate`` — no hand-maintained mode list — on CPU. Pallas
substrates run in interpret mode here (wall-clock kernel numbers only mean
something on real TPU); the XLA modes give the CPU-comparable throughput
picture and the relative cost of bit-exact emulation.

``sharded=True`` (``benchmarks.run --only kernel --sharded``) adds a
``dot_general`` + ``Partitioning`` sweep over a debug mesh of every visible
device (data-parallel M, reduce-scattered K) — the TPU-native benchmark run
uses it to sweep sharded contractions; under
``--xla_force_host_platform_device_count=N`` it exercises the same lowering
on CPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import substrate as sub


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _sharded_rows(specs, a8, b8, macs) -> list:
    """dot_general + Partitioning sweep over a debug mesh of all devices."""
    from repro.launch import mesh as mesh_lib

    rows = []
    mesh = mesh_lib.make_debug_mesh()
    part = mesh_lib.contraction_partitioning(mesh)
    print(f"\n== kernel bench: sharded dot_general "
          f"(mesh {dict(mesh.shape)}, m_axis={part.m_axis}, "
          f"k_axis={part.k_axis}) ==")
    for spec in specs:
        s = sub.get_substrate(spec)
        cspec = sub.ContractionSpec(partitioning=part)
        f = jax.jit(lambda a, b, _s=s, _c=cspec: _s.dot_general(a, b, _c))
        us = _time(f, a8, b8)
        gmacs = macs / us / 1e3
        print(f"{spec:>16s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s) [sharded]")
        rows.append((f"kernel/sharded_{s.meta.label}", us,
                     f"gmacs={gmacs:.2f};devices={mesh.size}"))
    return rows


def run(substrates=None, sharded=False) -> list:
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    a8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    specs = list(substrates) if substrates else sub.list_substrates()
    print(f"\n== kernel bench: int8 matmul substrates ({m}x{k}x{n}, CPU) ==")
    macs = m * k * n
    for spec in specs:
        s = sub.get_substrate(spec)
        f = jax.jit(lambda a, b, _s=s: _s.dot_int(a, b))
        us = _time(f, a8, b8)
        gmacs = macs / us / 1e3
        note = " [interpret]" if s.meta.preferred_backend == "tpu" \
            and jax.default_backend() != "tpu" else ""
        print(f"{spec:>16s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s){note}")
        rows.append((f"kernel/matmul_{s.meta.label}", us, f"gmacs={gmacs:.2f}"))

    if sharded:
        rows.extend(_sharded_rows(specs, a8, b8, macs))

    # pallas × wiring × width sweep: the LUT-input kernel makes every
    # wiring TPU-runnable; proposed@8 rides the closed-form fast path
    # (cost_hint "vpu"), everything else the flat-table gather ("gather").
    pm, pk, pn = 128, 128, 128
    pa = jnp.asarray(rng.integers(-128, 128, (pm, pk)), jnp.int8)
    pb = jnp.asarray(rng.integers(-128, 128, (pk, pn)), jnp.int8)
    pmacs = pm * pk * pn
    print(f"\n== kernel bench: pallas wiring x width sweep ({pm}x{pk}x{pn}) ==")
    for wiring in ("proposed", "csp_axc1", "design_strollo2020"):
        for width in (4, 8):
            spec = f"approx_pallas:{wiring}@{width}"
            s = sub.get_substrate(spec)
            f = jax.jit(lambda a, b, _s=s: _s.dot_int(a, b))
            us = _time(f, pa, pb)
            gmacs = pmacs / us / 1e3
            note = " [interpret]" if jax.default_backend() != "tpu" else ""
            print(f"{spec:>34s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s) "
                  f"[{s.meta.cost_hint}]{note}")
            rows.append((f"kernel/pallas_{wiring}@{width}", us,
                         f"gmacs={gmacs:.2f};cost={s.meta.cost_hint}"))

    from repro.kernels.approx_mul.ops import approx_mul
    x = jnp.asarray(rng.integers(-128, 128, (512, 512)), jnp.int32)
    y = jnp.asarray(rng.integers(-128, 128, (512, 512)), jnp.int32)
    us = _time(approx_mul, x, y)
    rows.append(("kernel/approx_mul_pallas_interp", us, "512x512"))
    print(f"pallas approx_mul (interpret): {us:.0f} us")
    return rows
