"""Kernel micro-benchmarks (beyond paper): approximate execution modes.

Times the XLA-lowered execution modes of the approximate matmul on CPU
(Pallas kernels are validated in interpret mode — wall-clock kernel numbers
only mean something on real TPU; the XLA modes give the CPU-comparable
throughput picture and the relative cost of bit-exact emulation).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import approx_dot as ad


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    m, k, n = 256, 512, 256
    a8 = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    print("\n== kernel bench: int8 matmul modes (256x512x256, CPU) ==")
    macs = m * k * n
    for mode in ("int8", "approx_stat", "approx_lut", "approx_bitexact"):
        f = jax.jit(lambda a, b, md=mode: ad.approx_matmul_int8(a, b, mode=md))
        us = _time(f, a8, b8)
        gmacs = macs / us / 1e3
        print(f"{mode:>16s}: {us:10.0f} us  ({gmacs:6.2f} GMAC/s)")
        rows.append((f"kernel/matmul_{mode}", us, f"gmacs={gmacs:.2f}"))

    from repro.kernels.approx_mul.ops import approx_mul
    x = jnp.asarray(rng.integers(-128, 128, (512, 512)), jnp.int32)
    y = jnp.asarray(rng.integers(-128, 128, (512, 512)), jnp.int32)
    us = _time(approx_mul, x, y)
    rows.append(("kernel/approx_mul_pallas_interp", us, "512x512"))
    print(f"pallas approx_mul (interpret): {us:.0f} us")
    return rows
