"""Edge-detection serving sweep: throughput/latency vs {batch, timeout,
substrate}.

Drives the micro-batching ``EdgeDetectService`` with a fixed request stream
per configuration and records throughput (img/s), p50/p95 latency, and mean
batch occupancy. One warmup request per service triggers compilation before
metrics are reset, so the table reflects steady-state serving.

Every configuration also lands in a machine-readable ``BENCH_serving.json``
next to the repo root (the serving counterpart of ``BENCH_kernels.json``),
including the ambient substrate-meter rollup — per-spec contraction
counts, MACs, and estimated energy (MACs × per-op PDP) — so the perf
trajectory carries serving numbers, not just kernel ones. ``--trace PATH``
additionally records the serving spans (queue wait, pad, compile, execute,
crop) as a Chrome/Perfetto trace.

Standalone:  PYTHONPATH=src python benchmarks/edge_serving.py [--dry-run]
             [--substrates exact,approx_lut] [--requests 32]
             [--json PATH] [--trace PATH]
Harness:     python -m benchmarks.run --only serve_edge
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax

from repro.data import image_batch
from repro.obs import (ContractionMeter, MetricsRegistry, Tracer,
                       telemetry_scope, tracing_scope, write_chrome_trace)
from repro.serving import EdgeDetectService

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = _REPO_ROOT / "BENCH_serving.json"

# (max_batch_size, max_wait_s) flush-policy sweep
SETTINGS = ((1, 0.0), (4, 0.002), (8, 0.002), (8, 0.010))

# CPU-feasible default sweep; the full registry is reachable via --substrates
# (approx_bitexact / approx_pallas interpret-mode are orders slower on CPU)
DEFAULT_SUBSTRATES = ("exact", "int8", "approx_lut", "approx_stat")


def _serve_once(spec: str, max_batch: int, max_wait_s: float,
                imgs) -> dict:
    svc = EdgeDetectService(spec, max_batch_size=max_batch,
                            max_wait_s=max_wait_s)
    try:
        svc.detect(imgs[:1])           # warmup: compile the bucket shape
        svc.metrics.reset()
        svc.detect(list(imgs))
        return svc.stats()
    finally:
        svc.close()


def run(substrates=None, dry_run: bool = False, n_requests: int = 32,
        json_path=DEFAULT_JSON, trace_path=None) -> list:
    specs = list(substrates) if substrates else list(DEFAULT_SUBSTRATES)
    settings = SETTINGS
    if dry_run:
        specs, settings, n_requests = specs[:1], SETTINGS[1:2], 6
    imgs = image_batch(n_requests, 32, 32, noise=1.5)

    tracer = Tracer() if trace_path else None
    meter = ContractionMeter(MetricsRegistry())
    rows = []
    records: list[dict] = []
    print("\n== edge serving: throughput vs {substrate, batch, timeout} ==")
    print(f"{'substrate':>16s} {'batch':>5s} {'wait_ms':>7s} {'img/s':>8s} "
          f"{'p50_ms':>7s} {'p95_ms':>7s} {'occ':>5s}")
    with tracing_scope(tracer), telemetry_scope(meter):
        for spec in specs:
            for max_batch, wait_s in settings:
                s = _serve_once(spec, max_batch, wait_s, imgs)
                assert s["requests_served"] == n_requests, s
                thrpt = s["throughput_rps"]
                us = 1e6 / thrpt if thrpt > 0 else float("inf")
                print(f"{spec:>16s} {max_batch:>5d} {wait_s * 1e3:>7.1f} "
                      f"{thrpt:>8.1f} {s['latency_p50_ms']:>7.2f} "
                      f"{s['latency_p95_ms']:>7.2f} "
                      f"{s['mean_occupancy']:>5.2f}")
                rows.append((
                    f"serve_edge/{spec}/b{max_batch}/w{wait_s * 1e3:g}ms", us,
                    f"thrpt={thrpt:.1f}img/s "
                    f"p50={s['latency_p50_ms']:.2f}ms "
                    f"p95={s['latency_p95_ms']:.2f}ms "
                    f"p99={s['latency_p99_ms']:.2f}ms "
                    f"occ={s['mean_occupancy']:.2f}"))
                records.append({
                    "spec": spec, "max_batch": max_batch,
                    "max_wait_ms": wait_s * 1e3,
                    "requests": n_requests,
                    "throughput_img_s": round(thrpt, 2),
                    "latency_p50_ms": round(s["latency_p50_ms"], 3),
                    "latency_p95_ms": round(s["latency_p95_ms"], 3),
                    "latency_p99_ms": round(s["latency_p99_ms"], 3),
                    "mean_occupancy": round(s["mean_occupancy"], 3),
                    "batches_flushed": s["batches_flushed"],
                    "batches_by_reason": s["batches_by_reason"],
                    "compiled_calls": s["compiled_calls"],
                })

    if json_path:
        payload = {
            "bench": "edge_serving",
            "backend": jax.default_backend(),
            "dry_run": bool(dry_run),
            "image_shape": [32, 32],
            "records": records,
            # ambient-meter rollup over the whole sweep (includes warmup):
            # per-spec contraction counts, MACs, estimated energy in fJ
            "substrate_meter": meter.summary(),
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1)
                                           + "\n")
        print(f"\nwrote {len(records)} records to {json_path}")
    if trace_path:
        p = write_chrome_trace(tracer, trace_path)
        print(f"wrote {len(tracer.events())} trace events to {p}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="single tiny configuration (CI wiring check)")
    ap.add_argument("--substrates", default=None,
                    help="CSV of substrate specs (default: CPU-feasible set)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--json", default=str(DEFAULT_JSON), dest="json_path",
                    help="output path for BENCH_serving.json ('' disables)")
    ap.add_argument("--trace", default=None, dest="trace_path",
                    help="write a Chrome/Perfetto trace of the serving spans")
    args = ap.parse_args()
    substrates = args.substrates.split(",") if args.substrates else None
    rows = run(substrates=substrates, dry_run=args.dry_run,
               n_requests=args.requests, json_path=args.json_path or None,
               trace_path=args.trace_path)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
