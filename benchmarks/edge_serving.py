"""Edge-detection serving sweep: throughput/latency vs {batch, timeout,
substrate}.

Drives the micro-batching ``EdgeDetectService`` with a fixed request stream
per configuration and records throughput (img/s), p50/p95 latency, and mean
batch occupancy. One warmup request per service triggers compilation before
metrics are reset, so the table reflects steady-state serving.

Every configuration also lands in a machine-readable ``BENCH_serving.json``
next to the repo root (the serving counterpart of ``BENCH_kernels.json``),
including the ambient substrate-meter rollup — per-spec contraction
counts, MACs, and estimated energy (MACs × per-op PDP) — so the perf
trajectory carries serving numbers, not just kernel ones. ``--trace PATH``
additionally records the serving spans (queue wait, pad, compile, execute,
crop) as a Chrome/Perfetto trace.

The sweep ends with a throughput-vs-worker-count table (workers 1/2/4)
for one substrate, in two modes per worker count:

* ``host`` — the raw substrate on this host. On a single hardware thread
  the contraction itself cannot parallelize, so this row mostly shows that
  multi-worker adds no overhead (and stays bit-identical).
* ``emulated`` — the service's ``device_latency_s`` knob holds each batch
  on an emulated device for the *measured* mean host batch time (an
  identity ``pure_callback`` stage inside the compiled call — values are
  untouched, see ``EdgeDetectService``). This is the accelerator-shaped
  regime the overlap design targets: device time ≳ host time, so workers
  hide one behind the other. Every row is checked bit-identical to the
  single-worker host reference.

The worker sweep runs in a child process with
``jax_cpu_enable_async_dispatch=False`` (the flag is only read when the
CPU client is created, so it cannot be toggled mid-process): XLA:CPU's
default async dispatch funnels every execution through one dispatch
thread, which would serialize concurrent batches — an artifact of the
host backend, not of the serving design. With synchronous dispatch each
execution runs on its worker thread, matching how concurrent batches
occupy a real accelerator.

Standalone:  PYTHONPATH=src python benchmarks/edge_serving.py [--dry-run]
             [--substrates exact,approx_lut] [--requests 32]
             [--json PATH] [--trace PATH]
Harness:     python -m benchmarks.run --only serve_edge
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np

from repro.data import image_batch
from repro.obs import (ContractionMeter, MetricsRegistry, Tracer,
                       telemetry_scope, tracing_scope, write_chrome_trace)
from repro.serving import EdgeDetectService

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = _REPO_ROOT / "BENCH_serving.json"

# (max_batch_size, max_wait_s) flush-policy sweep
SETTINGS = ((1, 0.0), (4, 0.002), (8, 0.002), (8, 0.010))

# CPU-feasible default sweep; the full registry is reachable via --substrates
# (approx_bitexact / approx_pallas interpret-mode are orders slower on CPU)
DEFAULT_SUBSTRATES = ("exact", "int8", "approx_lut", "approx_stat")


#: worker counts for the throughput-vs-worker-count table
WORKER_COUNTS = (1, 2, 4)

#: flush policy used by the worker sweep (batch 4 → several in-flight
#: batches even for modest request streams)
WORKER_SWEEP_BATCH = 4


def _serve_once(spec: str, max_batch: int, max_wait_s: float,
                imgs) -> dict:
    svc = EdgeDetectService(spec, max_batch_size=max_batch,
                            max_wait_s=max_wait_s)
    try:
        svc.detect(imgs[:1])           # warmup: compile the bucket shape
        svc.metrics.reset()
        svc.detect(list(imgs))
        return svc.stats()
    finally:
        svc.close()


def _serve_workers(spec: str, imgs, n_workers: int,
                   device_latency_s: float, ref=None):
    """One worker-sweep cell: stats, outputs, bit-identity vs ``ref``."""
    svc = EdgeDetectService(spec, max_batch_size=WORKER_SWEEP_BATCH,
                            n_workers=n_workers,
                            device_latency_s=device_latency_s)
    try:
        svc.detect(imgs[:1])           # warmup: compile the bucket shape
        svc.metrics.reset()
        out = svc.detect(list(imgs))
        identical = ref is None or (
            len(out) == len(ref)
            and all(np.array_equal(a, b) for a, b in zip(ref, out)))
        return svc.stats(), out, identical
    finally:
        svc.close()


def worker_sweep(spec: str, imgs, workers=WORKER_COUNTS) -> dict:
    """Throughput vs worker count, host + emulated-device modes.

    Returns the ``worker_sweep`` record for ``BENCH_serving.json`` and
    prints the table. Every cell is verified bit-identical to the
    single-worker host reference."""
    print(f"\n== edge serving: throughput vs workers ({spec}) ==")
    print(f"{'mode':>9s} {'workers':>7s} {'img/s':>8s} {'speedup':>7s} "
          f"{'p50_ms':>7s} {'inflight_peak':>13s} {'identical':>9s}")
    rows = []
    base = {}
    # host mode: the raw substrate; also yields the bit-identity reference
    # and the emulated-device latency calibration (mean batch busy time)
    ref = None
    cal_s = 0.0
    for w in workers:
        s, out, identical = _serve_workers(spec, imgs, w, 0.0, ref=ref)
        if ref is None:
            ref = out
            batches = sum(s["worker_batches"].values()) or 1
            busy = sum(float(v)
                       for v in s["worker_busy_seconds"].values())
            # floor: the emulated stage must dominate sleep-granularity +
            # GIL overhead, or the sleep measures the host, not the device
            cal_s = max(busy / batches, 4e-3)
        rows.append(("host", w, s, identical))
    # emulated mode: device as slow as the measured host batch time
    for w in workers:
        s, _, identical = _serve_workers(spec, imgs, w, cal_s, ref=ref)
        rows.append(("emulated", w, s, identical))
    out_rows = []
    for mode, w, s, identical in rows:
        thrpt = s["throughput_rps"]
        if w == workers[0]:
            base[mode] = thrpt
        speedup = thrpt / base[mode] if base[mode] > 0 else float("inf")
        print(f"{mode:>9s} {w:>7d} {thrpt:>8.1f} {speedup:>6.2f}x "
              f"{s['latency_p50_ms']:>7.2f} {s['inflight_peak']:>13d} "
              f"{str(identical):>9s}")
        out_rows.append({
            "mode": mode, "workers": w,
            "throughput_img_s": round(thrpt, 2),
            "speedup_vs_1": round(speedup, 3),
            "latency_p50_ms": round(s["latency_p50_ms"], 3),
            "inflight_peak": s["inflight_peak"],
            "worker_batches": s["worker_batches"],
            "bit_identical_to_1worker": bool(identical),
        })
    return {
        "spec": spec,
        "max_batch": WORKER_SWEEP_BATCH,
        "requests": len(imgs),
        "emulated_device_latency_ms": round(cal_s * 1e3, 3),
        "cpu_sync_dispatch": not jax.config._read(
            "jax_cpu_enable_async_dispatch"),
        "rows": out_rows,
    }


def _worker_sweep_subprocess(spec: str, n_requests: int,
                             dry_run: bool) -> dict:
    """Run :func:`worker_sweep` in a child process.

    ``jax_cpu_enable_async_dispatch`` is read once, when the CPU client is
    created — by the time the settings sweep has run it can no longer be
    turned off in this process, so the sweep gets a fresh interpreter that
    sets the flag first (see the module docstring for why it must be off).
    """
    cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
           "--worker-sweep-only", spec, "--requests", str(n_requests)]
    if dry_run:
        cmd.append("--dry-run")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_REPO_ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env)
    marker = "WORKER_SWEEP_JSON:"
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(marker):
            payload = json.loads(line[len(marker):])
        else:
            print(line)
    if proc.returncode != 0 or payload is None:
        raise RuntimeError(
            f"worker sweep subprocess failed (rc={proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    return payload


def run(substrates=None, dry_run: bool = False, n_requests: int = 32,
        json_path=DEFAULT_JSON, trace_path=None) -> list:
    specs = list(substrates) if substrates else list(DEFAULT_SUBSTRATES)
    settings = SETTINGS
    worker_counts = WORKER_COUNTS
    if dry_run:
        specs, settings, n_requests = specs[:1], SETTINGS[1:2], 6
        worker_counts = (1, 2)
    imgs = image_batch(n_requests, 32, 32, noise=1.5)

    tracer = Tracer() if trace_path else None
    meter = ContractionMeter(MetricsRegistry())
    rows = []
    records: list[dict] = []
    print("\n== edge serving: throughput vs {substrate, batch, timeout} ==")
    print(f"{'substrate':>16s} {'batch':>5s} {'wait_ms':>7s} {'img/s':>8s} "
          f"{'p50_ms':>7s} {'p95_ms':>7s} {'occ':>5s}")
    with tracing_scope(tracer), telemetry_scope(meter):
        for spec in specs:
            for max_batch, wait_s in settings:
                s = _serve_once(spec, max_batch, wait_s, imgs)
                assert s["requests_served"] == n_requests, s
                thrpt = s["throughput_rps"]
                us = 1e6 / thrpt if thrpt > 0 else float("inf")
                print(f"{spec:>16s} {max_batch:>5d} {wait_s * 1e3:>7.1f} "
                      f"{thrpt:>8.1f} {s['latency_p50_ms']:>7.2f} "
                      f"{s['latency_p95_ms']:>7.2f} "
                      f"{s['mean_occupancy']:>5.2f}")
                rows.append((
                    f"serve_edge/{spec}/b{max_batch}/w{wait_s * 1e3:g}ms", us,
                    f"thrpt={thrpt:.1f}img/s "
                    f"p50={s['latency_p50_ms']:.2f}ms "
                    f"p95={s['latency_p95_ms']:.2f}ms "
                    f"p99={s['latency_p99_ms']:.2f}ms "
                    f"occ={s['mean_occupancy']:.2f}"))
                records.append({
                    "spec": spec, "max_batch": max_batch,
                    "max_wait_ms": wait_s * 1e3,
                    "requests": n_requests,
                    "throughput_img_s": round(thrpt, 2),
                    "latency_p50_ms": round(s["latency_p50_ms"], 3),
                    "latency_p95_ms": round(s["latency_p95_ms"], 3),
                    "latency_p99_ms": round(s["latency_p99_ms"], 3),
                    "mean_occupancy": round(s["mean_occupancy"], 3),
                    "batches_flushed": s["batches_flushed"],
                    "batches_by_reason": s["batches_by_reason"],
                    "compiled_calls": s["compiled_calls"],
                })

        # throughput-vs-worker-count table on the paper's served substrate
        # (child process: needs jax_cpu_enable_async_dispatch=False)
        sweep_spec = "approx_lut" if "approx_lut" in specs else specs[0]
        sweep = _worker_sweep_subprocess(sweep_spec, n_requests, dry_run)
        for row in sweep["rows"]:
            rows.append((
                f"serve_edge/{sweep_spec}/workers{row['workers']}"
                f"/{row['mode']}",
                1e6 / row["throughput_img_s"]
                if row["throughput_img_s"] > 0 else float("inf"),
                f"thrpt={row['throughput_img_s']:.1f}img/s "
                f"speedup={row['speedup_vs_1']:.2f}x "
                f"inflight_peak={row['inflight_peak']} "
                f"identical={row['bit_identical_to_1worker']}"))

    if json_path:
        payload = {
            "bench": "edge_serving",
            "backend": jax.default_backend(),
            "dry_run": bool(dry_run),
            "image_shape": [32, 32],
            "records": records,
            "worker_sweep": sweep,
            # ambient-meter rollup over the whole sweep (includes warmup):
            # per-spec contraction counts, MACs, estimated energy in fJ
            "substrate_meter": meter.summary(),
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1)
                                           + "\n")
        print(f"\nwrote {len(records)} records to {json_path}")
    if trace_path:
        p = write_chrome_trace(tracer, trace_path)
        print(f"wrote {len(tracer.events())} trace events to {p}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="single tiny configuration (CI wiring check)")
    ap.add_argument("--substrates", default=None,
                    help="CSV of substrate specs (default: CPU-feasible set)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--json", default=str(DEFAULT_JSON), dest="json_path",
                    help="output path for BENCH_serving.json ('' disables)")
    ap.add_argument("--trace", default=None, dest="trace_path",
                    help="write a Chrome/Perfetto trace of the serving spans")
    ap.add_argument("--worker-sweep-only", default=None, metavar="SPEC",
                    help="internal: run only the worker sweep for SPEC and "
                         "print its JSON record (spawned as a subprocess so "
                         "the CPU client is created with synchronous "
                         "dispatch)")
    args = ap.parse_args()
    if args.worker_sweep_only:
        # must happen before the first computation creates the CPU client
        jax.config.update("jax_cpu_enable_async_dispatch", False)
        n = 6 if args.dry_run else args.requests
        counts = (1, 2) if args.dry_run else WORKER_COUNTS
        imgs = image_batch(n, 32, 32, noise=1.5)
        record = worker_sweep(args.worker_sweep_only, list(imgs),
                              workers=counts)
        print("WORKER_SWEEP_JSON:" + json.dumps(record))
        return
    substrates = args.substrates.split(",") if args.substrates else None
    rows = run(substrates=substrates, dry_run=args.dry_run,
               n_requests=args.requests, json_path=args.json_path or None,
               trace_path=args.trace_path)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
