"""Edge-detection serving sweep: throughput/latency vs {batch, timeout,
substrate}.

Drives the micro-batching ``EdgeDetectService`` with a fixed request stream
per configuration and records throughput (img/s), p50/p95 latency, and mean
batch occupancy. One warmup request per service triggers compilation before
metrics are reset, so the table reflects steady-state serving.

Standalone:  PYTHONPATH=src python benchmarks/edge_serving.py [--dry-run]
             [--substrates exact,approx_lut] [--requests 32]
Harness:     python -m benchmarks.run --only serve_edge
"""
from __future__ import annotations

import argparse

from repro.data import image_batch
from repro.serving import EdgeDetectService

# (max_batch_size, max_wait_s) flush-policy sweep
SETTINGS = ((1, 0.0), (4, 0.002), (8, 0.002), (8, 0.010))

# CPU-feasible default sweep; the full registry is reachable via --substrates
# (approx_bitexact / approx_pallas interpret-mode are orders slower on CPU)
DEFAULT_SUBSTRATES = ("exact", "int8", "approx_lut", "approx_stat")


def _serve_once(spec: str, max_batch: int, max_wait_s: float,
                imgs) -> dict:
    svc = EdgeDetectService(spec, max_batch_size=max_batch,
                            max_wait_s=max_wait_s)
    try:
        svc.detect(imgs[:1])           # warmup: compile the bucket shape
        svc.metrics.reset()
        svc.detect(list(imgs))
        return svc.stats()
    finally:
        svc.close()


def run(substrates=None, dry_run: bool = False, n_requests: int = 32) -> list:
    specs = list(substrates) if substrates else list(DEFAULT_SUBSTRATES)
    settings = SETTINGS
    if dry_run:
        specs, settings, n_requests = specs[:1], SETTINGS[1:2], 6
    imgs = image_batch(n_requests, 32, 32, noise=1.5)

    rows = []
    print("\n== edge serving: throughput vs {substrate, batch, timeout} ==")
    print(f"{'substrate':>16s} {'batch':>5s} {'wait_ms':>7s} {'img/s':>8s} "
          f"{'p50_ms':>7s} {'p95_ms':>7s} {'occ':>5s}")
    for spec in specs:
        for max_batch, wait_s in settings:
            s = _serve_once(spec, max_batch, wait_s, imgs)
            assert s["requests_served"] == n_requests, s
            thrpt = s["throughput_rps"]
            us = 1e6 / thrpt if thrpt > 0 else float("inf")
            print(f"{spec:>16s} {max_batch:>5d} {wait_s * 1e3:>7.1f} "
                  f"{thrpt:>8.1f} {s['latency_p50_ms']:>7.2f} "
                  f"{s['latency_p95_ms']:>7.2f} {s['mean_occupancy']:>5.2f}")
            rows.append((
                f"serve_edge/{spec}/b{max_batch}/w{wait_s * 1e3:g}ms", us,
                f"thrpt={thrpt:.1f}img/s "
                f"p50={s['latency_p50_ms']:.2f}ms "
                f"p95={s['latency_p95_ms']:.2f}ms "
                f"p99={s['latency_p99_ms']:.2f}ms "
                f"occ={s['mean_occupancy']:.2f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="single tiny configuration (CI wiring check)")
    ap.add_argument("--substrates", default=None,
                    help="CSV of substrate specs (default: CPU-feasible set)")
    ap.add_argument("--requests", type=int, default=32)
    args = ap.parse_args()
    substrates = args.substrates.split(",") if args.substrates else None
    rows = run(substrates=substrates, dry_run=args.dry_run,
               n_requests=args.requests)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
