"""Plan-vs-uniform energy/quality table for autotuned substrate plans.

Evaluates a per-site :class:`repro.nn.plan.SubstratePlan` against the
uniform ``proposed@8`` baseline on the edge-detection workload: estimated
PDP energy (MACs × unit-gate PDP, ``obs.meter`` pricing), PSNR vs the exact
multiplier, and wall time of the planned pipeline.

``run(plan=...)`` evaluates a given plan (a plan JSON file or a plan-bundle
directory — e.g. the artifact ``python -m repro.launch.autotune`` wrote);
without one it runs the fast greedy autotuner search first and evaluates
its winner. Results land in ``BENCH_autotune.json`` at the repo root
alongside the other machine-readable bench artifacts.

Standalone: ``python -m benchmarks.autotune_plan [--plan PATH]``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.data import image_batch
from repro.launch import autotune
from repro.nn import conv
from repro.nn import plan as plan_mod

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_JSON = _REPO_ROOT / "BENCH_autotune.json"

BASELINE = "approx_bitexact:proposed@8"
WIRINGS = ("proposed", "design_du2022")
WIDTHS = (6, 7, 8)


def _load_plan(path) -> plan_mod.SubstratePlan:
    p = pathlib.Path(path)
    if p.is_dir():
        from repro import checkpoint as ckpt

        plan, _, _ = ckpt.load_plan_bundle(str(p))
        return plan
    return plan_mod.load_plan(str(p))


def run(plan=None, json_path=DEFAULT_JSON) -> list:
    rows = []
    imgs = image_batch(6, 64, 64)
    ref = np.asarray(conv.edge_detect_batched(imgs, "exact"))

    search = None
    if plan is not None:
        tuned, source = _load_plan(plan), str(plan)
    else:
        t0 = time.perf_counter()
        res = autotune.autotune_edge(images=imgs, wirings=WIRINGS,
                                     widths=WIDTHS, baseline=BASELINE)
        search_us = (time.perf_counter() - t0) * 1e6
        tuned, source = res["plan"], "greedy search"
        search = {"budget_scored_db": res["budget_scored_db"],
                  "accepted_moves": len(res["history"]) - 1,
                  "rolled_back": res["rolled_back"]}
        rows.append(("autotune/search", search_us,
                     f"moves={search['accepted_moves']}"))

    print(f"\n== Autotune: plan vs uniform {BASELINE} ({source}) ==")
    print(f"{'variant':>10s} {'psnr_db':>8s} {'pdp_fj':>12s} {'us':>10s}")
    records = {}
    for name, p in (("uniform", plan_mod.SubstratePlan.uniform(BASELINE)),
                    ("plan", tuned)):
        site_macs = autotune.measure_site_macs(
            lambda pp: np.asarray(conv.edge_detect_planned(imgs, pp)), p)
        pdp = autotune.plan_pdp_fj(site_macs, p)
        out = np.asarray(conv.edge_detect_planned(imgs, p))  # warm (compiled)
        t0 = time.perf_counter()
        out = np.asarray(conv.edge_detect_planned(imgs, p))
        us = (time.perf_counter() - t0) * 1e6
        db = conv.psnr(ref, out)
        print(f"{name:>10s} {db:8.2f} {pdp:12.1f} {us:10.0f}")
        records[name] = {"plan": p.to_dict(), "psnr_db": db, "pdp_fj": pdp,
                         "us_per_batch": us, "site_macs": site_macs}
        rows.append((f"autotune/{name}", us,
                     f"psnr={db:.2f}dB,pdp={pdp:.0f}fJ"))
    saved = 1 - records["plan"]["pdp_fj"] / records["uniform"]["pdp_fj"]
    print(f"energy saved by plan: {100 * saved:.1f}% "
          f"(dPSNR {records['plan']['psnr_db'] - records['uniform']['psnr_db']:+.2f} dB)")

    if json_path:
        payload = {"workload": "edge", "images": "6x64x64",
                   "baseline_spec": BASELINE, "plan_source": source,
                   "search": search, "energy_saved_frac": saved,
                   **records}
        pathlib.Path(json_path).write_text(
            json.dumps(payload, indent=1) + "\n")
        print(f"[bench autotune] wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="plan JSON or plan-bundle dir to evaluate "
                         "(default: run the greedy search first)")
    ap.add_argument("--json", default=str(DEFAULT_JSON), dest="json_path")
    args = ap.parse_args()
    run(plan=args.plan, json_path=args.json_path)
