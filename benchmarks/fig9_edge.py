"""Paper Fig. 9: edge-detection PSNR per multiplier design.

The paper reports PSNR on an unspecified image with unspecified
postprocessing (proposed: 20.13 dB). PSNR is strongly image/harness
dependent (see EXPERIMENTS.md §Fig9) — we report our harness (pixels>>1,
clip-[0,255]) on both a geometric test card and a photo-statistics image.

Everything runs through the batched substrate pipeline
(``nn.conv.edge_detect_batched``): the design sweep enumerates every wiring
in ``core.multiplier.ALL_MULTIPLIERS`` through the LUT substrate
(bit-identical to the scalar loop), and a second sweep times an 8-image
batch on every registered substrate — no hand-maintained mode lists.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import multiplier as mult
from repro.data import image_batch, photo_like, test_image
from repro.nn import conv
from repro.nn import substrate as sub


def run(substrates=None) -> list:
    rows = []
    designs = [n for n in mult.default_width_names() if n != "exact"]
    for img_name, img in (("testcard", test_image(96, 96)),
                          ("photo", photo_like(128, 128))):
        batch = img[None]
        ref = np.asarray(conv.edge_detect_batched(batch, "exact"))[0]
        print(f"\n== Fig 9: edge detection PSNR vs exact ({img_name}) ==")
        for name in designs:
            s = sub.get_substrate("approx_lut", mult_name=name)
            t0 = time.perf_counter()
            out = np.asarray(conv.edge_detect_batched(batch, s))[0]
            us = (time.perf_counter() - t0) * 1e6
            p = conv.psnr(ref, out)
            print(f"{name:>22s} PSNR={p:6.2f} dB")
            rows.append((f"fig9/{img_name}/{name}", us, f"psnr={p:.2f}dB"))

    # batched pipeline (8 images) across every registered substrate
    imgs = image_batch(8, 64, 64)
    specs = list(substrates) if substrates else sub.list_substrates()
    print("\n== Fig 9: batched edge detection (8x64x64) per substrate ==")
    for spec in specs:
        s = sub.get_substrate(spec)
        t0 = time.perf_counter()
        _ = np.asarray(conv.edge_detect_batched(imgs, s))
        us = (time.perf_counter() - t0) * 1e6
        print(f"{spec:>16s}: {us:10.0f} us/batch")
        rows.append((f"fig9/batched8/{s.meta.label}", us, "imgs=8x64x64"))

    # width sweep: the proposed wiring at 4/8/16-bit operand width (the
    # response is rescaled to the 8-bit range, so PSNR is comparable), plus
    # the pallas × wiring × width rows the LUT kernel unlocks — every
    # wiring is now TPU-runnable, not just proposed@8 (interpret off-TPU)
    img = photo_like(128, 128)
    ref = np.asarray(conv.edge_detect_batched(img[None], "exact"))[0]
    print("\n== Fig 9+: operand-width sweep (incl. pallas wirings) ==")
    for spec in ("approx_lut:proposed@4", "approx_lut:proposed",
                 "approx_bitexact:proposed@16",
                 "approx_pallas:proposed@4", "approx_pallas:csp_axc1@4",
                 "approx_pallas:design_strollo2020"):
        t0 = time.perf_counter()
        out = np.asarray(conv.edge_detect_batched(img[None], spec))[0]
        us = (time.perf_counter() - t0) * 1e6
        p = conv.psnr(ref, out)
        print(f"{spec:>28s} PSNR={p:6.2f} dB")
        rows.append((f"fig9/width/{spec}", us, f"psnr={p:.2f}dB"))

    # fused conv kernel path (im2col inside the kernel; interpret on CPU)
    from repro.kernels.fused_conv.ops import fused_conv2d
    img = test_image(96, 96)
    px = (np.asarray(img, np.int32) >> 1)[None]
    t0 = time.perf_counter()
    _ = np.asarray(fused_conv2d(px, conv.LAPLACIAN, "proposed"))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig9/pallas_fused_conv", us, "interpret=True"))
    print(f"pallas fused_conv (interpret): {us:.0f} us")
    return rows
