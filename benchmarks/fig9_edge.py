"""Paper Fig. 9: edge-detection PSNR per multiplier design.

The paper reports PSNR on an unspecified image with unspecified
postprocessing (proposed: 20.13 dB). PSNR is strongly image/harness
dependent (see EXPERIMENTS.md §Fig9) — we report our harness (pixels>>1,
clip-[0,255]) on both a geometric test card and a photo-statistics image,
plus the Pallas-kernel path timing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data import photo_like, test_image
from repro.nn import conv


def run() -> list:
    rows = []
    designs = ["proposed", "design_du2022", "design_strollo2020",
               "design_du2024", "design_guo2019", "design_esposito2018",
               "design_akbari2017", "design_krishna2024"]
    for img_name, img in (("testcard", test_image(96, 96)),
                          ("photo", photo_like(128, 128))):
        ref = np.asarray(conv.edge_detect(img, "exact"))
        print(f"\n== Fig 9: edge detection PSNR vs exact ({img_name}) ==")
        for name in designs:
            t0 = time.perf_counter()
            out = np.asarray(conv.edge_detect(img, name))
            us = (time.perf_counter() - t0) * 1e6
            p = conv.psnr(ref, out)
            print(f"{name:>22s} PSNR={p:6.2f} dB")
            rows.append((f"fig9/{img_name}/{name}", us, f"psnr={p:.2f}dB"))

    # Pallas kernel path (interpret mode on CPU)
    from repro.kernels.laplacian_conv.ops import laplacian_conv
    img = test_image(96, 96)
    px = (np.asarray(img, np.int32) >> 1)
    t0 = time.perf_counter()
    _ = np.asarray(laplacian_conv(px))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("fig9/pallas_kernel", us, "interpret=True"))
    print(f"pallas laplacian_conv (interpret): {us:.0f} us")
    return rows
