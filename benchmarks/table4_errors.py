"""Paper Table 4: exhaustive ER / NMED / MRED for all multiplier designs."""
from __future__ import annotations

import time

from repro.core import metrics
from repro.core import multiplier as m


def run() -> list:
    rows = []
    print("\n== Table 4: error metrics (exhaustive, 65 536 operand pairs) ==")
    print(f"{'design':>22s} {'ER%':>7s} {'paper':>7s} {'NMED%':>7s} {'paper':>7s} "
          f"{'MRED%':>7s} {'paper':>7s}")
    order = ["design_strollo2020", "design_guo2019", "design_esposito2018",
             "design_akbari2017", "design_krishna2024", "design_du2022",
             "proposed", "trunc_exact_csp", "exact"]
    for name in order:
        t0 = time.perf_counter()
        rep = metrics.evaluate(m.ALL_MULTIPLIERS[name], name)
        us = (time.perf_counter() - t0) * 1e6
        p = metrics.PAPER_TABLE4.get(name, {})
        print(f"{name:>22s} {rep.er * 100:7.2f} {p.get('er', float('nan')):7.2f} "
              f"{rep.nmed * 100:7.3f} {p.get('nmed', float('nan')):7.3f} "
              f"{rep.mred * 100:7.2f} {p.get('mred', float('nan')):7.2f}")
        rows.append((f"table4/{name}", us,
                     f"ER={rep.er * 100:.2f};NMED={rep.nmed * 100:.3f};"
                     f"MRED={rep.mred * 100:.2f}"))
    print("note: [1]/[7] rows are reconstructed baselines (no truth tables in "
          "the paper); proposed matches NMED within 0.035 pp and MRED within "
          "0.2 pp of Table 4.")
    return rows
