"""Paper Fig. 10: PDP vs MRED trade-off scatter data."""
from __future__ import annotations

import time

from repro.core import energy, metrics
from repro.core import multiplier as m


def run() -> list:
    rows = []
    print("\n== Fig 10: PDP (fJ) vs MRED (%) trade-off ==")
    print(f"{'design':>22s} {'PDP':>8s} {'MRED%':>7s}")
    pts = []
    for name in energy.PAPER_TABLE5:
        if name == "exact":
            continue
        t0 = time.perf_counter()
        pdp = energy.estimate(name)["pdp"]
        mred = metrics.evaluate(m.ALL_MULTIPLIERS[name], name).mred * 100
        us = (time.perf_counter() - t0) * 1e6
        pts.append((name, pdp, mred))
        print(f"{name:>22s} {pdp:8.1f} {mred:7.2f}")
        rows.append((f"fig10/{name}", us, f"pdp={pdp:.1f};mred={mred:.2f}"))
    best = min(pts, key=lambda x: x[1] + x[2] * 5)
    prop = next(p for p in pts if p[0] == "proposed")
    pareto = [p for p in pts
              if not any(q[1] < p[1] and q[2] < p[2] for q in pts)]
    on_pareto = any(p[0] == "proposed" for p in pareto)
    print(f"proposed on Pareto front: {on_pareto} "
          f"(paper: lowest PDP and lowest MRED)")
    rows.append(("fig10/pareto", 0.0, f"proposed_on_front={on_pareto}"))
    return rows
