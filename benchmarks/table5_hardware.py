"""Paper Table 5: area/power/delay/PDP via the unit-gate analytical model."""
from __future__ import annotations

import time

from repro.core import energy


def run() -> list:
    rows = []
    print("\n== Table 5: hardware model (unit-gate, calibrated on exact row) ==")
    print(f"{'design':>22s} {'area':>8s} {'paper':>8s} {'power':>7s} {'paper':>7s} "
          f"{'delay':>6s} {'paper':>6s} {'PDP':>7s} {'paper':>7s}")
    for name, paper in energy.PAPER_TABLE5.items():
        t0 = time.perf_counter()
        e = energy.estimate(name)
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name:>22s} {e['area']:8.1f} {paper['area']:8.1f} "
              f"{e['power']:7.1f} {paper['power']:7.1f} "
              f"{e['delay']:6.2f} {paper['delay']:6.2f} "
              f"{e['pdp']:7.1f} {paper['pdp']:7.1f}")
        rows.append((f"table5/{name}", us,
                     f"power={e['power']:.1f}uW;pdp={e['pdp']:.1f}fJ"))
    s = energy.savings_vs("proposed", "design_du2022")
    print(f"proposed vs [2]: power -{s['power']:.2f}% (paper -14.39%), "
          f"delay -{s['delay']:.2f}% (paper -17.3%), "
          f"PDP -{s['pdp']:.2f}% (paper -29.21%)")
    sx = energy.savings_vs("proposed", "exact")
    print(f"proposed vs exact: power -{sx['power']:.2f}%, PDP -{sx['pdp']:.2f}%")
    rows.append(("table5/savings_vs_du2022", 0.0,
                 f"power={s['power']:.2f}%;pdp={s['pdp']:.2f}%"))
    return rows
