"""Fault-tolerant training loop.

Production invariants, scaled to whatever mesh is present:

* **checkpoint/restart** — async checkpoints every ``ckpt_every`` steps;
  on (re)start the loop discovers the newest complete checkpoint, restores
  params/opt-state *with the current mesh's shardings* (elastic), and seeks
  the data stream to the exact step — bitwise-resumable.
* **failure injection** — ``fail_at_step`` raises mid-run (tests use it to
  prove crash→restart equivalence).
* **straggler mitigation** — step-time EWMA; steps slower than
  ``straggler_factor``× the EWMA are counted and surfaced in metrics (on a
  real cluster this signal feeds the scheduler; here it drives the metric
  surface + tests).
* **gradient compression** — optional int8 all-reduce via shard_map for the
  data-parallel axis (see ``dp_train_step_compressed``).
* **grad accumulation** — microbatching for global batches that exceed
  memory.
* **approximation-aware training** — set ``cfg.qat`` to a
  :class:`repro.train.qat.QATPolicy` (optionally with ``cfg.plan``) and the
  loss traces inside :func:`repro.train.qat.qat_scope`: every plan-resolved
  contraction runs the approximate substrate forward with a
  straight-through backward. A non-None ``cfg.plan`` *governs* the trace —
  the loss is traced inside
  :func:`repro.nn.plan.plan_override_scope(cfg.plan)`, so every
  plan-consulting contraction resolves through it regardless of what the
  model function was built with. The active plan + policy are recorded in
  each checkpoint manifest and re-applied on restore: an unset
  ``cfg.plan``/``cfg.qat`` adopts the checkpoint's (effectively — the
  adopted plan is installed in the trace, not just logged), a conflicting
  one raises. A resumed QAT run therefore cannot silently continue under
  different numerics (see docs/training.md).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.optim import grad_utils
from repro.optim.adamw import Optimizer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    lr: float = 1e-3
    grad_clip: float = 1.0
    grad_accum: int = 1
    fail_at_step: Optional[int] = None       # fault-injection hook
    straggler_factor: float = 3.0
    async_ckpt: bool = True
    qat: Optional[Any] = None                # repro.train.qat.QATPolicy
    plan: Optional[Any] = None               # SubstratePlan / spec / dict


class TrainLoop:
    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 cfg: TrainLoopConfig, lr_schedule: Optional[Callable] = None):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.cfg = cfg
        if cfg.plan is not None:
            from repro.nn import plan as _plan_mod
            cfg.plan = _plan_mod.as_plan(cfg.plan)
        self.lr_schedule = lr_schedule or (lambda step: cfg.lr)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.metrics: Dict[str, Any] = {"straggler_steps": 0, "resumed_from": None}
        self._step_fn = self._build_step()

    def _ckpt_extra(self) -> Dict[str, Any]:
        """Manifest record of the numerics this run trains under."""
        extra: Dict[str, Any] = {}
        if self.cfg.plan is not None:
            extra["plan"] = self.cfg.plan.to_dict()
        if self.cfg.qat is not None:
            extra["qat"] = self.cfg.qat.describe()
        return extra

    def _build_step(self):
        cfg = self.cfg

        def one_micro(params, batch):
            # trace-time ambients: entering the scopes inside the traced
            # body installs the plan + STE overrides for exactly this trace,
            # so cfg.plan/cfg.qat (including checkpoint-adopted values) are
            # what the contraction actually runs, not just what is logged
            with contextlib.ExitStack() as scopes:
                if cfg.plan is not None:
                    from repro.nn import plan as _plan_mod
                    scopes.enter_context(
                        _plan_mod.plan_override_scope(cfg.plan))
                if cfg.qat is not None:
                    from repro.train import qat as qat_mod
                    scopes.enter_context(qat_mod.qat_scope(cfg.qat))
                return jax.value_and_grad(self.loss_fn)(params, batch)

        def step(params, opt_state, batch, lr):
            if cfg.grad_accum == 1:
                loss, grads = one_micro(params, batch)
            else:
                def micro(i, carry):
                    acc_loss, acc_grads = carry
                    mb = jax.tree_util.tree_map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // cfg.grad_accum),
                            x.shape[0] // cfg.grad_accum, axis=0), batch)
                    l, g = one_micro(params, mb)
                    return (acc_loss + l,
                            jax.tree_util.tree_map(jnp.add, acc_grads, g))
                zero = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                loss, grads = jax.lax.fori_loop(
                    0, cfg.grad_accum, micro, (jnp.zeros((), jnp.float32), zero))
                scale = 1.0 / cfg.grad_accum
                loss = loss * scale
                grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            grads, gnorm = grad_utils.clip_by_global_norm(grads, cfg.grad_clip)
            new_params, new_state = self.optimizer.update(
                grads, opt_state, params, lr=lr)
            return loss, gnorm, new_params, new_state

        return jax.jit(step)

    # -- lifecycle -----------------------------------------------------------

    def init_or_restore(self, init_params_fn: Callable, shardings=None):
        """Fresh init, or restore newest checkpoint (elastic) + seek step."""
        params = init_params_fn()
        opt_state = self.optimizer.init(params)
        start_step = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            tree, step, extra = self.ckpt.restore(
                {"params": params, "opt": opt_state}, shardings=shardings)
            params, opt_state = tree["params"], tree["opt"]
            start_step = step
            self.metrics["resumed_from"] = step
            self._check_numerics(extra or {})
        return params, opt_state, start_step

    def _check_numerics(self, extra: Dict[str, Any]):
        """Refuse to resume under different numerics than the checkpoint's.

        A QAT checkpoint is only meaningful together with the plan/policy it
        trained under; an absent ``cfg.plan``/``cfg.qat`` adopts the
        checkpoint's, a conflicting one raises. Adoption is *effective*, not
        cosmetic: the adopted plan/policy land in ``cfg`` before the step
        function has traced, and the step traces the loss inside
        ``plan_override_scope(cfg.plan)`` / ``qat_scope(cfg.qat)`` — so the
        resumed contractions run the checkpoint's numerics even though the
        model function was built earlier. The step function is rebuilt on
        adoption so no previously traced program can be reused.
        """
        from repro.nn import plan as _plan_mod
        adopted = False
        saved_plan = extra.get("plan")
        if saved_plan is not None:
            saved = _plan_mod.as_plan(saved_plan)
            if self.cfg.plan is None:
                self.cfg.plan = saved
                adopted = True
            elif self.cfg.plan != saved:
                raise ValueError(
                    f"checkpoint was trained under plan {saved.label!r} "
                    f"but this run configures {self.cfg.plan.label!r}; "
                    "pass the matching --dot-plan (or none, to adopt the "
                    "checkpoint's)")
        saved_qat = extra.get("qat")
        if saved_qat is not None:
            from repro.train import qat as qat_mod
            saved_pol = qat_mod.QATPolicy.from_dict(saved_qat)
            if self.cfg.qat is None:
                # an approximate-plan resume without the checkpoint's STE
                # policy would run the integer forward un-wrapped: jnp.round
                # has zero gradient a.e. — silent training breakage, not a
                # numerics preference. Adopt, symmetric with the plan above.
                self.cfg.qat = saved_pol
                adopted = True
            elif self.cfg.qat != saved_pol:
                raise ValueError(
                    f"checkpoint QAT policy {saved_qat} differs from this "
                    f"run's {self.cfg.qat.describe()}")
        if adopted:
            self._step_fn = self._build_step()

    def run(self, params, opt_state, data_stream, start_step: int = 0,
            on_step: Optional[Callable] = None):
        cfg = self.cfg
        data_stream.seek(start_step)
        ewma = None
        losses = []
        step = start_step
        try:
            for step in range(start_step, cfg.total_steps):
                if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                    raise RuntimeError(f"injected failure at step {step}")
                batch = {k: jnp.asarray(v) for k, v in data_stream.next().items()}
                t0 = time.time()
                lr = jnp.float32(self.lr_schedule(step))
                loss, gnorm, params, opt_state = self._step_fn(
                    params, opt_state, batch, lr)
                loss = float(loss)
                dt = time.time() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > cfg.straggler_factor * ewma and step > start_step + 3:
                    self.metrics["straggler_steps"] += 1
                losses.append(loss)
                if on_step:
                    on_step(step, loss)
                if (step + 1) % cfg.ckpt_every == 0:
                    tree = {"params": params, "opt": opt_state}
                    extra = self._ckpt_extra()
                    if cfg.async_ckpt:
                        self.ckpt.save_async(step + 1, tree, extra=extra)
                    else:
                        self.ckpt.save(step + 1, tree, extra=extra)
        finally:
            self.ckpt.wait()
        self.metrics["final_loss"] = losses[-1] if losses else None
        self.metrics["losses"] = losses
        return params, opt_state, step + 1


# ---------------------------------------------------------------------------
# shard_map data-parallel step with int8-compressed gradient all-reduce
# ---------------------------------------------------------------------------


def dp_train_step_compressed(loss_fn, optimizer, mesh, axis_name: str = "data",
                             compress: bool = True):
    """Explicit-collective DP step: per-shard grads → int8 psum → update.

    The pjit path reduces gradients implicitly; this shard_map variant makes
    the all-reduce explicit so it can be compressed (8× fewer gradient
    bytes on the wire — the paper's quantization theme applied to the
    collective layer).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def sharded_step(params, opt_state, batch, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            grads = grad_utils.compressed_psum(grads, axis_name)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, axis_name), grads)
        loss = jax.lax.pmean(loss, axis_name)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr=lr)
        return loss, new_params, new_state

    pspec_batch = P(axis_name)
    return jax.jit(shard_map(
        sharded_step, mesh=mesh,
        in_specs=(P(), P(), {"tokens": pspec_batch, "labels": pspec_batch}, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    ))
