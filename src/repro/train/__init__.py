"""Training loop with fault tolerance + approximation-aware training."""
from repro.train.loop import TrainLoop, TrainLoopConfig  # noqa: F401
from repro.train.qat import (  # noqa: F401
    QATPolicy, qat_dot_general, qat_scope)
