"""Approximation-aware training (QAT): differentiable approximate forward.

The integer contraction paths of :mod:`repro.nn.substrate` are not usefully
differentiable — ``jnp.round`` at the quantization boundary has zero
gradient almost everywhere, so training a model whose ``dense()`` runs on an
approximate substrate silently produces zero weight gradients. This module
makes the approximate forward *trainable* via the standard straight-through
estimator (STE — the canonical move in the approximate-multiplier-for-DNN
literature, survey arxiv 2301.12181):

* **forward** — exactly the substrate's own path: quantize → the wiring's
  bit-exact / LUT / statistical integer product model → dequantize. Values
  are bit-identical to inference on that substrate (and the ambient
  :class:`~repro.obs.meter.ContractionMeter` sees the contraction the same
  way — MAC/PDP attribution keeps working during training).
* **backward** — the VJP of the *float* product ``x @ w`` under the same
  dimension numbers, treating the whole quantize→approx→dequantize chain as
  identity. Optionally, the separable error-moment model behind
  ``approx_stat`` (the per-operand conditional means of
  :func:`repro.core.lut.error_lut`, whose global aggregates are
  :func:`repro.core.lut.error_moments`) contributes a first-order
  correction: for the model ``f(a,b) ≈ a·b + r(a) + c(b) − µ``, the
  backward adds ``r'(a)``/``c'(b)`` slope terms, so gradients see the
  wiring's operand-dependent bias, not just the exact product.

Composition with :class:`~repro.nn.plan.SubstratePlan` is ambient:
:func:`qat_scope` installs the STE wrapper through
:func:`repro.nn.substrate.dot_override_scope`, so every
``models.common.dense`` call keeps resolving its site through the config's
plan — per-site specs (e.g. ``conv.edge.center → proposed@6``) train under
their *own* wiring's error. ``QATPolicy(forward="stat")`` rewrites each
resolved spec to its MXU-friendly ``approx_stat`` counterpart for fast
training epochs (validate on the bit-exact spec afterwards).

The module also carries the trainable edge-detection workload (a float 3×3
kernel + affine output calibration whose forward is the planned tap-group
contraction of :func:`repro.nn.conv.edge_detect_planned`) and its
:func:`finetune_edge` recovery loop — the paper-side half of
``benchmarks/qat_recovery.py``. See docs/training.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.nn import conv as conv_lib
from repro.nn import plan as plan_mod
from repro.nn import substrate as psub

Array = jnp.ndarray

_FORWARD_MODES = ("bitexact", "stat")


@dataclasses.dataclass(frozen=True)
class QATPolicy:
    """How a resolved (site → spec) assignment contracts during training.

    forward:            ``"bitexact"`` runs each resolved spec as-is (the
                        deployment numerics); ``"stat"`` rewrites approx
                        specs through :func:`repro.nn.plan.stat_spec` to the
                        separable error-moment model — same wiring + width,
                        MXU-friendly HLO — for cheap training epochs.
    moment_correction:  add the separable error model's ``r'(a)``/``c'(b)``
                        slope terms to the STE backward (see module
                        docstring). Off by default: plain STE is the
                        well-understood baseline.
    """

    forward: str = "bitexact"
    moment_correction: bool = False

    def __post_init__(self):
        if self.forward not in _FORWARD_MODES:
            raise ValueError(
                f"QATPolicy.forward must be one of {_FORWARD_MODES}; "
                f"got {self.forward!r}")

    def forward_spec(self, spec_str: str) -> str:
        """The spec the QAT forward actually runs for ``spec_str``."""
        return (plan_mod.stat_spec(spec_str) if self.forward == "stat"
                else spec_str)

    def describe(self) -> Dict[str, Any]:
        """JSON-serializable record (checkpoint manifests, bundles)."""
        return {"forward": self.forward,
                "moment_correction": self.moment_correction}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "QATPolicy":
        return cls(forward=d.get("forward", "bitexact"),
                   moment_correction=bool(d.get("moment_correction", False)))


# ---------------------------------------------------------------------------
# the straight-through contraction
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _slope_tables(mult_key: str):
    """Discrete slopes of the separable error model's r/c tables.

    ``_stat_tables`` fits ``E[e(a,b)] ≈ r[a] + c[b] − µ`` on the exhaustive
    error LUT (rows ordered by signed operand value, same convention as
    :func:`repro.core.lut.error_lut`); central finite differences of r and c
    are the first-order sensitivities of the expected error to each operand.
    """
    r, c, _mu = psub._stat_tables(mult_key)
    return (np.gradient(r.astype(np.float64)).astype(np.float32),
            np.gradient(c.astype(np.float64)).astype(np.float32))


def _unplan3(t3: Array, shape, perm) -> Array:
    """Invert ``_Plan.lhs3``/``rhs3``: (B,·,·) → the operand's own layout."""
    inv = tuple(int(i) for i in np.argsort(perm))
    return t3.reshape(tuple(shape[p] for p in perm)).transpose(inv)


def _moment_terms(sub, cspec: psub.ContractionSpec, plan, x: Array, w: Array,
                  g: Array):
    """Error-moment STE correction terms (dx_corr, dw_corr).

    With the separable model the output is
    ``out_f[m,n] = sx·sw[n] · Σ_k (a·b + r(a) + c(b) − µ)`` where
    ``a = x/sx``, ``b = w/sw``. Differentiating the r/c terms:
    ``∂out_f/∂x[m,k] += sw[n]·r'(a[m,k])`` and
    ``∂out_f/∂w[k,n] += sx[m]·c'(b[k,n])`` — the exact-product part is the
    plain STE term. Quantization reuses the forward's own policy, so the
    slopes are sampled at the operand codes the wiring actually saw.
    """
    q = cspec.quant
    n = sub.meta.width
    bits = q.bits if q.bits is not None else n
    off = 1 << (n - 1)
    qa, sa = psub._quantize_operand(plan.lhs3(x), q.x_mode, q.x_scale,
                                    contract_axis=2, bits=bits, eps=q.eps)
    qb, sb = psub._quantize_operand(plan.rhs3(w), q.w_mode, q.w_scale,
                                    contract_axis=1, bits=bits, eps=q.eps)
    rp, cp = _slope_tables(sub.meta.mult_key)
    g3 = g.astype(jnp.float32).reshape(plan.b, plan.m, plan.n)
    sa = jnp.asarray(sa, jnp.float32)
    sb = jnp.asarray(sb, jnp.float32)
    ai = (qa.astype(jnp.int32) + off) & ((1 << n) - 1)
    bi = (qb.astype(jnp.int32) + off) & ((1 << n) - 1)
    # Σ_n g[m,n]·sw[n] and Σ_m g[m,n]·sx[m] (scales broadcast: scalar or
    # per-channel (B,1,N)/(B,M,1) from _quantize_operand)
    gw = (g3 * sb).sum(axis=2, keepdims=True)            # (B, M, 1)
    ga = (g3 * sa).sum(axis=1, keepdims=True)            # (B, 1, N)
    dx3 = jnp.asarray(rp)[ai] * gw                       # (B, M, K)
    dw3 = jnp.asarray(cp)[bi] * ga                       # (B, K, N)
    return (_unplan3(dx3, x.shape, plan.lhs_perm),
            _unplan3(dw3, w.shape, plan.rhs_perm))


def _moment_correctable(sub, cspec: psub.ContractionSpec) -> bool:
    return (cspec.quant is not None and sub.meta.mult_name != "exact"
            and sub.meta.width <= lut_lib.MAX_LUT_BITS)


def _build_ste(spec_str: str, cspec: psub.ContractionSpec, moment: bool):
    sub = psub.get_substrate(spec_str)

    @jax.custom_vjp
    def ste(x, w):
        return sub.dot_general(x, w, cspec)

    def fwd(x, w):
        return sub.dot_general(x, w, cspec), (x, w)

    def bwd(res, g):
        x, w = res
        plan = psub._plan_contraction(x.shape, w.shape,
                                      cspec.dimension_numbers)

        def float_dot(xx, ww):
            return jax.lax.dot_general(xx.astype(jnp.float32),
                                       ww.astype(jnp.float32), plan.dims)

        _, pullback = jax.vjp(float_dot, x, w)
        dx, dw = pullback(g.astype(jnp.float32))
        if moment and _moment_correctable(sub, cspec):
            dxc, dwc = _moment_terms(sub, cspec, plan, x, w, g)
            dx = dx + dxc.astype(dx.dtype)
            dw = dw + dwc.astype(dw.dtype)
        return dx, dw

    ste.defvjp(fwd, bwd)
    return ste


@functools.lru_cache(maxsize=None)
def _ste_fn_cached(spec_str, cspec, moment):
    return _build_ste(spec_str, cspec, moment)


def _ste_fn(spec_str: str, cspec: psub.ContractionSpec, moment: bool):
    try:
        return _ste_fn_cached(spec_str, cspec, moment)
    except TypeError:  # unhashable spec (e.g. array-pinned quant scales)
        return _build_ste(spec_str, cspec, moment)


def qat_dot_general(x: Array, w: Array, spec_str: str,
                    cspec: Optional[psub.ContractionSpec] = None,
                    policy: Optional[QATPolicy] = None) -> Array:
    """Differentiable contraction of float operands on an approximate spec.

    Forward values are bit-identical to
    ``get_substrate(policy.forward_spec(spec_str)).dot_general(x, w, cspec)``;
    the backward is the straight-through estimator of the module docstring.
    Exact-backend specs short-circuit to the substrate's native float path,
    which is already differentiable (STE on it would be an identical
    gradient at extra trace cost).
    """
    policy = policy if policy is not None else QATPolicy()
    cspec = (cspec if cspec is not None
             else psub.ContractionSpec.matmul(quant=psub.QuantPolicy()))
    if cspec.quant is None:
        raise ValueError(
            "QAT contractions need a QuantPolicy (float operands); the "
            "integer-domain dot_general has no float gradient to estimate")
    fwd_spec = policy.forward_spec(spec_str)
    sub = psub.get_substrate(fwd_spec)
    if sub.meta.name == "exact":
        return sub.dot_general(x, w, cspec)
    return _ste_fn(fwd_spec, cspec, policy.moment_correction)(x, w)


@contextlib.contextmanager
def qat_scope(policy: Optional[QATPolicy] = None):
    """Route every plan-resolved model contraction through the STE wrapper.

    Installs :func:`qat_dot_general` as the ambient
    :func:`repro.nn.substrate.dot_override_scope` hook, so
    ``models.common.dense`` (and any other consulting call site) contracts
    differentiably on whatever spec the config's
    :class:`~repro.nn.plan.SubstratePlan` resolves per site — including the
    ``lax.switch`` branches of mixed per-layer plans under ``lax.scan``.
    Trace-time ambient (thread-local): wrap the *loss call* that is being
    traced, as :class:`repro.train.loop.TrainLoop` does for its QAT steps.
    """
    policy = policy if policy is not None else QATPolicy()

    def _override(spec_str, x, w, cspec):
        return qat_dot_general(x, w, spec_str, cspec, policy)

    with psub.dot_override_scope(_override):
        yield policy


# ---------------------------------------------------------------------------
# trainable edge-detection workload (the paper's application, QAT-ified)
# ---------------------------------------------------------------------------


def init_edge_params() -> Dict[str, Array]:
    """Float Laplacian kernel + affine output calibration (gain·resp + bias).

    At init the forward reproduces :func:`repro.nn.conv.edge_detect_planned`
    bit-for-bit (gain 1, bias 0, integer-valued kernel); training moves the
    float master kernel through the round() STE and the calibration pair
    absorbs the wiring's mean response error.
    """
    return {"kernel": jnp.asarray(conv_lib.LAPLACIAN, jnp.float32),
            "gain": jnp.ones((), jnp.float32),
            "bias": jnp.zeros((), jnp.float32)}


#: pinned unit scales: pixels/coefficients are already integer-domain values,
#: so quantization is a pure round() (identity on the integer init) and the
#: dequantized response equals the integer tap-group response exactly.
_EDGE_QUANT = psub.QuantPolicy(x_mode="per_tensor", w_mode="per_tensor",
                               x_scale=1.0, w_scale=1.0)


def edge_response(params: Dict[str, Array], imgs_u8: Array, plan,
                  policy: Optional[QATPolicy] = None) -> Array:
    """Differentiable planned edge response (float, 8-bit scale, unclipped).

    Mirrors :func:`repro.nn.conv.edge_detect_planned`: per tap group the
    pixels map into the resolved substrate's operand width and the group
    contracts on that substrate (through :func:`qat_dot_general`, so
    coefficient gradients flow); group responses rescale to the 8-bit range
    and sum, then the affine calibration applies. Plan widths must be ≤ 8
    (same contract as the planned integer path) and ≥ 5 so the Laplacian's
    center tap stays inside the symmetric quantizer's clip range — the
    integer path wraps where this path clips.
    """
    plan = plan_mod.as_plan(plan)
    imgs = jnp.asarray(imgs_u8)
    kernel = params["kernel"].reshape(-1)
    total = None
    for name, taps in conv_lib._EDGE_TAP_GROUPS:
        site = f"{conv_lib.EDGE_SITE}.{name}"
        spec_str = plan.resolve(site)
        n = getattr(psub.get_substrate(spec_str).meta, "width", 8)
        if not 5 <= n <= 8:
            raise ValueError(
                f"QAT edge plan widths must be in [5, 8]; site {site} "
                f"resolved to {spec_str!r} (width {n})")
        idx = np.asarray(taps, np.int32)
        px = conv_lib.to_signed_pixels(imgs, n).astype(jnp.float32)
        patches = conv_lib._im2col(px, 3, 3)[..., idx]
        coeffs = kernel[idx].reshape(len(taps), 1)
        cspec = psub.ContractionSpec(conv_lib._CONV_DIMS, quant=_EDGE_QUANT,
                                     site=site)
        raw = qat_dot_general(patches, coeffs, spec_str, cspec, policy)[..., 0]
        r = raw * float(1 << (8 - n))
        total = r if total is None else total + r
    return params["gain"] * total + params["bias"]


def edge_reference_response(imgs_u8: Array) -> Array:
    """Exact float Laplacian response at the 8-bit scale (training target)."""
    px = conv_lib.to_signed_pixels(imgs_u8, 8).astype(jnp.float32)
    patches = conv_lib._im2col(px, 3, 3)
    k = jnp.asarray(conv_lib.LAPLACIAN, jnp.float32).reshape(-1)
    return (patches * k).sum(-1)


def edge_maps(params: Dict[str, Array], imgs_u8: Array, plan,
              policy: Optional[QATPolicy] = None) -> Array:
    """uint8 edge maps of the QAT edge model (clip + round, PSNR-comparable)."""
    resp = edge_response(params, imgs_u8, plan, policy)
    return jnp.clip(jnp.round(resp), 0, 255).astype(jnp.uint8)


def edge_psnr(params: Dict[str, Array], imgs_u8: Array, plan,
              policy: Optional[QATPolicy] = None) -> float:
    """PSNR (dB) of the QAT edge model against the exact-multiplier maps."""
    ref = conv_lib.edge_detect_batched(imgs_u8, "exact")
    return conv_lib.psnr(ref, edge_maps(params, imgs_u8, plan, policy))


def calibrate_edge(params: Dict[str, Array], imgs_u8: Array, plan,
                   policy: Optional[QATPolicy] = None) -> Dict[str, Array]:
    """Closed-form affine calibration: least-squares (gain, bias) fit.

    One forward pass; fits ``gain·resp + bias ≈ target`` on the unclipped
    responses. Standard post-training calibration — QAT then refines the
    kernel itself on top.
    """
    base = {**params, "gain": jnp.ones((), jnp.float32),
            "bias": jnp.zeros((), jnp.float32)}
    resp = edge_response(base, imgs_u8, plan, policy).reshape(-1)
    target = edge_reference_response(imgs_u8).reshape(-1)
    rm, tm = resp.mean(), target.mean()
    var = jnp.maximum(((resp - rm) ** 2).mean(), 1e-6)
    gain = ((resp - rm) * (target - tm)).mean() / var
    bias = tm - gain * rm
    return {**params, "gain": gain.astype(jnp.float32),
            "bias": bias.astype(jnp.float32)}


def finetune_edge(imgs_u8, plan, *, steps: int = 120, lr: float = 0.1,
                  policy: Optional[QATPolicy] = None,
                  params: Optional[Dict[str, Array]] = None,
                  calibrate: bool = True) -> Dict[str, Any]:
    """QAT fine-tune of the edge model under ``plan``'s wirings.

    Loss is the MSE between the (unclipped) QAT response and the exact
    float Laplacian response — clipping only applies at eval, so gradients
    reach pixels the wiring's bias pushed out of range. Returns
    ``{"params", "losses", "psnr_pre", "psnr_post"}`` where the PSNRs are
    evaluated on the *bit-exact* forward regardless of ``policy.forward``.
    """
    from repro.optim import adamw

    policy = policy if policy is not None else QATPolicy()
    plan = plan_mod.as_plan(plan)
    imgs = jnp.asarray(imgs_u8)
    params = dict(params) if params is not None else init_edge_params()
    eval_policy = QATPolicy(forward="bitexact")
    # pre-PSNR of the *starting point* — a caller's warm-start params (e.g.
    # the autotuner's adapted params riding through repeated calls), not a
    # fresh init
    psnr_pre = edge_psnr(params, imgs, plan, eval_policy)

    target = edge_reference_response(imgs)

    def loss_fn(p):
        resp = edge_response(p, imgs, plan, policy)
        return jnp.mean((resp - target) ** 2)

    # seed "best" with the starting point so a short/unlucky run can never
    # return params worse (by loss) than what it was given
    best = (float(loss_fn(params)), params)
    if calibrate:
        params = calibrate_edge(params, imgs, plan, policy)
        cal_loss = float(loss_fn(params))
        if cal_loss < best[0]:
            best = (cal_loss, params)
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(grads, s, p, lr=jnp.float32(lr))
        return loss, p2, s2

    losses: List[float] = []
    for _ in range(int(steps)):
        prev = params
        loss, params, state = step(prev, state)
        losses.append(float(loss))   # loss at `prev`, pre-update
        if losses[-1] < best[0]:
            best = (losses[-1], prev)
    if steps:
        final = float(loss_fn(params))
        if final < best[0]:
            best = (final, params)
    params = best[1]
    psnr_post = edge_psnr(params, imgs, plan, eval_policy)
    return {"params": params, "losses": losses,
            "psnr_pre": float(psnr_pre), "psnr_post": float(psnr_post)}
