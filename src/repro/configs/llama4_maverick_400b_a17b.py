"""llama4-maverick-400b-a17b [moe] — MoE top-1, interleaved every 2nd layer.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1 +
shared expert [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
Interleave=2 reproduces the ~400B total / ~17B active split.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="lm",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_interleave=2,
    shared_expert=True,
    rope_theta=5e5,
))
