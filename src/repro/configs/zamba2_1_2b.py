"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H d_ff=8192 vocab=32000, ssm_state=64; one shared
attention block applied every 6 mamba layers. Sub-quadratic: serves
long_500k (O(1) mamba state + shared-block KV).
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="zamba",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    shared_attn_every=6,
))
