"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3; unverified]. Local window 1024.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="lm",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    local_window=1024,
    local_global_ratio=5,
    rope_theta=1e6,
))
