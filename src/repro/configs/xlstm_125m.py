"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 (blocks carry their own projections) vocab=50304.
Sub-quadratic: serves long_500k.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
))
