"""whisper-large-v3 [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280 20H (kv=20) d_ff=5120
vocab=51866; 1500 post-conv audio frames (stub embeddings).
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    n_frames=1500,
    rope_theta=1e4,
))
