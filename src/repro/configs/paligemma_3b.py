"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216. The SigLIP
frontend is a STUB: input_specs() provides 256 precomputed patch embeddings.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    n_patches=256,
    rope_theta=1e4,
))
