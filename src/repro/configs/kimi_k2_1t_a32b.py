"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert dim) vocab=163840,
MoE 384e top-8 + shared expert [arXiv:2501.kimi2; unverified].
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="lm",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
    moe_interleave=1,
    shared_expert=True,
    rope_theta=5e4,
))
