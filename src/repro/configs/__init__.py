"""Assigned architecture configs (one module per arch) + the paper's own app.

Importing this package registers every config with the model registry.
"""
from repro.configs import (  # noqa: F401
    edge_detect,
    gemma3_27b,
    internlm2_20b,
    kimi_k2_1t_a32b,
    llama4_maverick_400b_a17b,
    minitron_8b,
    paligemma_3b,
    qwen1_5_32b,
    whisper_large_v3,
    xlstm_125m,
    zamba2_1_2b,
)
