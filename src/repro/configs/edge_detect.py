"""The paper's own application config: approximate Laplacian edge detection.

Not an LM — selects the conv pipeline + Pallas kernel; registered for
--arch completeness so the paper's app is a first-class config.

``dot_mode`` is a ProductSubstrate spec (``repro.nn.substrate``); the
parameterized form pins the multiplier wiring explicitly. Override to
``"approx_pallas"`` for the TPU kernel path (any wiring/width ≤ 8 via the
LUT kernel, e.g. ``"approx_pallas:csp_axc1@4"``) or
``"approx_lut:<design>"`` for any baseline wiring.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="edge-detect",
    family="lm",            # placeholder family; launchers special-case it
    n_layers=1,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=64,
    vocab=256,
    dot_mode="approx_bitexact:proposed",
))
