"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5; hf].

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
"""
from repro.models.common import ModelConfig
from repro.models.registry import register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family="lm",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
))
