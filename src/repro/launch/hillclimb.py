"""Perf hillclimbing driver (§Perf methodology).

For a chosen (arch × shape) cell, lowers named VARIANTS — config knobs
and/or logical-sharding-rule overrides — and reports the corrected roofline
terms for each, so a hypothesis → change → measure → validate loop can be
driven from the EXPERIMENTS.md log.

  python -m repro.launch.hillclimb --arch kimi-k2-1t-a32b --shape train_4k \
      --variants base,remat_off,attn_chunk_2048 --out results_hillclimb.json

Production meshes need 512 (emulated) host devices, which XLA only grants
via ``XLA_FLAGS`` set *before* backend initialization. That mutation is
opt-in now: it runs under ``python -m repro.launch.hillclimb`` (the
``__main__`` block calls :func:`force_host_devices` before any JAX call) —
merely importing this module (e.g. for :data:`VARIANTS` or
:func:`corrected_with`) no longer touches the process environment.
"""
import argparse
import json
import os
import time
import traceback

import jax

from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.launch.rooffix import COST_ATTN_CHUNK, COST_LOSS_CHUNK, _metrics_for
from repro.models import lm
from repro.models import registry as reg
from repro.models import sharding as sh


def force_host_devices(count: int = 512) -> None:
    """Opt in to the emulated multi-device host platform.

    Appends ``--xla_force_host_platform_device_count=<count>`` to
    ``XLA_FLAGS`` (preserving ``_DRYRUN_EXTRA_XLA``). Call before JAX
    initializes its backend or the flag is ignored.
    """
    os.environ["XLA_FLAGS"] = (
        os.environ.get("_DRYRUN_EXTRA_XLA", "") +
        f" --xla_force_host_platform_device_count={count}").strip()

# variant -> (config overrides, logical-rule overrides)
VARIANTS = {
    "base": ({}, {}),
    # activation-checkpoint policy: no remat (recompute flops vanish; peak
    # memory grows — validated against memory_analysis)
    "remat_off": ({"remat": False}, {}),
    # attention KV-chunk sizing (VMEM-tile analogue): fewer, larger chunks
    "attn_chunk_4096": ({"attn_chunk": 4096}, {}),
    "attn_chunk_8192": ({"attn_chunk": 8192}, {}),
    # chunked-loss tile
    "loss_chunk_4096": ({"loss_chunk": 4096}, {}),
    # sequence parallelism: shard activation seq dim over the model axis
    "seq_shard": ({}, {"seq": "model"}),
    # keep experts' capacity dim fully data-sharded but drop the shared
    # expert (ablation of llama4/kimi shared path)
    "moe_cap_1.0": ({"capacity_factor": 1.0}, {}),
    "moe_cap_2.0": ({"capacity_factor": 2.0}, {}),
    # embedding replicated (kills the vocab all-gather at the loss, pays
    # memory) — collective-term experiment
    "emb_replicated": ({}, {"vocab": None}),
    # decode: shard KV heads over model only (no seq shard of the cache)
    "kv_headshard": ({"_cache_shard": "heads"}, {}),
    # long-decode base: 8192-wide cost chunks (decode score tiles are tiny;
    # bounds the cost-unroll compile time)
    "long_base": ({"attn_chunk": 8192}, {}),
    "long_kvhead": ({"attn_chunk": 8192, "_cache_shard": "heads"}, {}),
    # decode: TP-only weights (resident; kills per-step FSDP gathers)
    "long_tponly": ({"attn_chunk": 8192, "_no_fsdp": "1"}, {}),
    # decode: head-sharded KV cache (no seq shard -> no cache permutes)
    "long_heads": ({"attn_chunk": 8192, "_cache_shard": "heads"}, {}),
    "attn_chunk_1024c": ({"attn_chunk": 1024}, {}),
    "attn_chunk_1024": ({"attn_chunk": 1024}, {}),
    # smaller chunks: the SSD intra-chunk quadratic work/memory is LINEAR
    # in the chunk size (B·S·c·H) — shrink it
    "attn_chunk_256": ({"attn_chunk": 256}, {}),
    "attn_chunk_128": ({"attn_chunk": 128}, {}),
    # paper's technique at scale: int8 matmuls + separable error correction
    "approx_stat": ({"dot_plan": "approx_stat"}, {}),
}


def corrected_with(arch: str, shape_name: str, overrides: dict, rules: dict):
    """Corrected (scan-aware) per-device metrics under variant settings."""
    shape = reg.SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    overrides = dict(overrides)
    cache_mode = overrides.pop("_cache_shard", None)
    if cache_mode:
        os.environ["REPRO_CACHE_SHARD"] = cache_mode
    if overrides.pop("_no_fsdp", None):
        os.environ["REPRO_NO_FSDP"] = "1"
    merged_rules = dict(sh.DEFAULT_RULES)
    merged_rules.update(rules)
    sh.set_rules(merged_rules)
    try:
        base_cfg = reg.get_config(arch, cost_unroll=True, **overrides)
        cost_over = dict(overrides)
        cost_over.setdefault("attn_chunk", COST_ATTN_CHUNK)
        cost_over.setdefault("loss_chunk", COST_LOSS_CHUNK)
        overrides = {k: v for k, v in overrides.items()}
        if base_cfg.family in ("xlstm", "zamba"):
            cfg = reg.get_config(arch, cost_unroll=True, **cost_over)
            m = _metrics_for(cfg, shape, mesh)
            jax.clear_caches()
            return m
        period = lm.unit_period(base_cfg)
        o0, o1 = dict(cost_over), dict(cost_over)
        o0["n_layers"] = 0
        o1["n_layers"] = period
        if base_cfg.family == "encdec":
            o0["n_encoder_layers"] = 0
            o1["n_encoder_layers"] = 1
        m0 = _metrics_for(reg.get_config(arch, cost_unroll=True, **o0), shape, mesh)
        jax.clear_caches()
        m1 = _metrics_for(reg.get_config(arch, cost_unroll=True, **o1), shape, mesh)
        jax.clear_caches()
        scale = base_cfg.n_layers / period
        return {k: m0[k] + scale * (m1[k] - m0[k]) for k in ("flops", "bytes", "coll")}
    finally:
        sh.set_rules(None)
        os.environ.pop("REPRO_CACHE_SHARD", None)
        os.environ.pop("REPRO_NO_FSDP", None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(reg.SHAPES))
    ap.add_argument("--variants", default="base")
    ap.add_argument("--out", default="results_hillclimb.json")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["variant"]) for r in results if r.get("ok")}

    cfg = reg.get_config(args.arch)
    shape = reg.SHAPES[args.shape]
    n_active = None
    for v in args.variants.split(","):
        if (args.arch, args.shape, v) in done:
            print(f"[skip] {v}")
            continue
        overrides, rules = VARIANTS[v]
        print(f"[hillclimb] {args.arch} × {args.shape} × {v} ...", flush=True)
        t0 = time.time()
        try:
            m = corrected_with(args.arch, args.shape, overrides, rules)
            rf = roofline.Roofline(
                flops_per_device=m["flops"], bytes_per_device=m["bytes"],
                collective_bytes=m["coll"], n_devices=256,
                model_flops=roofline.model_flops_for(cfg, shape),
            )
            r = dict(arch=args.arch, shape=args.shape, variant=v, ok=True,
                     flops_per_device=m["flops"], bytes_per_device=m["bytes"],
                     collective_bytes=m["coll"], secs=round(time.time() - t0, 1),
                     **rf.row())
            print(f"  ok: comp={rf.t_compute:.3f}s mem={rf.t_memory:.3f}s "
                  f"coll={rf.t_collective:.3f}s bneck={rf.bottleneck} "
                  f"rooffrac={rf.roofline_fraction:.4f}", flush=True)
        except Exception as e:  # noqa: BLE001
            r = dict(arch=args.arch, shape=args.shape, variant=v, ok=False,
                     error=f"{type(e).__name__}: {e}",
                     traceback=traceback.format_exc()[-1500:])
            print(f"  FAIL: {r['error']}", flush=True)
        results.append(r)
        json.dump(results, open(args.out, "w"), indent=1, default=str)


if __name__ == "__main__":
    force_host_devices()
    main()
