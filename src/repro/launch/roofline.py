"""Roofline-term derivation from compiled dry-run artifacts.

Three terms, per (arch × shape × mesh), all in seconds (TPU v5e targets):

  compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
  collective = collective_bytes_per_device / link_bw        (~50 GB/s/link)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis: we parse the post-SPMD HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~3 links usable; 1-link worst case)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:[0-9]+)?|pred)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in post-SPMD HLO."""
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op lines look like: %name = bf16[256,1024]{1,0} all-reduce(...)
        m = re.search(r"=\s*(\(?[a-z0-9\[\],{}\s]+\)?)\s+([a-z\-]+)", stripped)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVES:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        total = sum(_tensor_bytes(dt, dims) for dt, dims in shapes)
        bytes_by[op] += total
        count_by[op] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    n_devices: int
    model_flops: float          # 6·N·D (train) or 2·N_active·D (inference)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/dispatch overhead detector)."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of compute roofline: useful-compute time over
        the dominating term (bound estimate, not a wall-clock measurement)."""
        t_useful = (self.model_flops / self.n_devices) / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict:
        return dict(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )


def normalize_cost_analysis(cost_analysis) -> dict:
    """``Compiled.cost_analysis()`` → one flat dict, across JAX versions.

    Older JAX returns ``[{...}]`` (one dict per executable program), newer
    returns the dict directly; either may be ``None``. Multiple program
    dicts are summed key-wise (numeric values only).
    """
    if not cost_analysis:
        return {}
    if isinstance(cost_analysis, dict):
        return cost_analysis
    merged: Dict[str, float] = {}
    for entry in cost_analysis:
        for k, v in (entry or {}).items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + float(v)
    return merged


def derive(cost_analysis, hlo_text: str, n_devices: int,
           model_flops: float) -> Roofline:
    cost_analysis = normalize_cost_analysis(cost_analysis)
    flops = float(cost_analysis.get("flops", 0.0))
    byts = float(cost_analysis.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes=float(coll.total_bytes),
        n_devices=n_devices,
        model_flops=model_flops,
    )


def model_flops_for(cfg, shape, n_active: float | None = None) -> float:
    """MODEL_FLOPS per step: 6·N_active·D (train) / 2·N_active·D (fwd).

    n_active: measured active-parameter count (falls back to the config
    formula when not provided).
    """
    if n_active is None:
        n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
