"""Production mesh + parameter sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16×16 = 256 chips (TPU v5e pod),
axes ("data", "model"). Multi-pod: 2×16×16 = 512 chips, axes
("pod", "data", "model") — the "pod" axis carries pure data parallelism
across the DCN/ICI boundary.

Parameter sharding is FSDP+TP hybrid, assigned by leaf-path name rules:
the contraction/feature dims of the big weights shard over ("pod","data")
(FSDP — gathered per layer under the scan) and the head/mlp/expert output
dims over "model" (TP/EP). Dims that don't divide evenly stay unsharded.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: Optional[int] = None) -> Mesh:
    """Small mesh over however many devices exist (tests)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def contraction_partitioning(mesh: Mesh, *, m_axis: str = "data",
                             k_axis: Optional[str] = "model"):
    """Substrate :class:`~repro.nn.substrate.Partitioning` for this mesh.

    Data-parallel M over ``m_axis``, reduce-scattered K over ``k_axis``.
    An axis missing from the mesh is dropped (a data-only debug mesh still
    works, k-sharding simply off); multi-pod meshes keep M on the single
    data axis — the "pod" axis stays pure batch parallelism.
    """
    from repro.nn import substrate as psub

    m = m_axis if m_axis in mesh.axis_names else None
    k = k_axis if (k_axis and k_axis in mesh.axis_names) else None
    return psub.Partitioning(mesh, m_axis=m, k_axis=k)


# ---------------------------------------------------------------------------
# name-based parameter sharding rules
# ---------------------------------------------------------------------------

_FSDP = ("pod", "data")

# leaf-name -> PartitionSpec for the *trailing* dims (leading scan/stack dims
# are added as None automatically). Rules are matched on the last two path
# components, most-specific first.
_RULES = [
    (("router",), P(None, "model")),
    (("moe", "wi"), P("model", _FSDP, None)),
    (("moe", "wg"), P("model", _FSDP, None)),
    (("moe", "wo"), P("model", None, _FSDP)),
    (("wq", "w"), P(_FSDP, "model")),
    (("wk", "w"), P(_FSDP, "model")),
    (("wv", "w"), P(_FSDP, "model")),
    (("wo", "w"), P("model", _FSDP)),
    (("wi", "w"), P(_FSDP, "model")),
    (("wg", "w"), P(_FSDP, "model")),
    (("wz", "w"), P(_FSDP, "model")),
    (("wf", "w"), P(_FSDP, "model")),
    (("wo_gate", "w"), P(_FSDP, "model")),
    (("in_proj", "w"), P(_FSDP, "model")),
    (("out_proj", "w"), P("model", _FSDP)),
    (("patch_proj", "w"), P(_FSDP, "model")),
    (("emb",), P("model", _FSDP)),
]


def _path_names(path) -> list:
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
    return names


def _match_rule(names: list) -> Optional[P]:
    for pattern, spec in _RULES:
        lp = len(pattern)
        # match pattern against the tail of the name path (ignoring numeric
        # components, which come from lists/stacked structures)
        alpha = [n for n in names if not n.isdigit()]
        if tuple(alpha[-lp:]) == pattern:
            return spec
        # optimizer-state leaves live one level deeper (m/v/vr/vc)
        if alpha and alpha[-1] in ("m", "v", "vr", "vc") and \
                tuple(alpha[-lp - 1:-1]) == pattern:
            return spec
    return None


def _fit_spec(spec: P, shape, mesh: Mesh, path_names) -> P:
    """Right-align the rule to the leaf shape; drop non-dividing axes.

    Factored optimizer leaves (vr: rule minus last dim, vc: rule minus
    second-to-last) are handled by name.
    """
    dims = list(spec)
    leaf = path_names[-1] if path_names else ""
    if leaf == "vr":
        dims = dims[:-1]
    elif leaf == "vc":
        dims = dims[:-2] + dims[-1:] if len(dims) >= 2 else dims
    if len(dims) > len(shape):
        dims = dims[-len(shape):]
    full = [None] * (len(shape) - len(dims)) + dims
    out = []
    for size, d in zip(shape, full):
        if d is None:
            out.append(None)
            continue
        names = d if isinstance(d, tuple) else (d,)
        present = tuple(n for n in names if n in mesh.axis_names)
        prod = int(np.prod([mesh.shape[n] for n in present])) if present else 1
        if not present or size % prod != 0:
            out.append(None)
        else:
            out.append(present if len(present) > 1 else present[0])
    return P(*out)


def param_shardings(tree, mesh: Mesh):
    """NamedSharding tree for params (or optimizer state) by name rules.

    REPRO_NO_FSDP=1 drops the ("pod","data") weight sharding (TP-only,
    weights resident) — the right trade for decode, where per-step FSDP
    gathers dominate collectives (hillclimb knob)."""
    import os as _os
    no_fsdp = _os.environ.get("REPRO_NO_FSDP")

    def leaf(path, x):
        names = _path_names(path)
        spec = _match_rule(names)
        if spec is None:
            return NamedSharding(mesh, P())
        if no_fsdp:
            dims = [None if (isinstance(d, tuple) or d in ("pod", "data"))
                    else d for d in spec]
            spec = P(*dims)
        return NamedSharding(mesh, _fit_spec(spec, x.shape, mesh, names))

    return jax.tree_util.tree_map_with_path(leaf, tree)


def batch_shardings(tree, mesh: Mesh):
    """Inputs: batch dim over ("pod","data"), rest unsharded; scalars repl."""
    def leaf(_path, x):
        if not hasattr(x, "shape") or len(x.shape) == 0:
            return NamedSharding(mesh, P())
        present = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
        prod = int(np.prod([mesh.shape[n] for n in present]))
        if x.shape[0] % prod == 0:
            return NamedSharding(mesh, P(present, *([None] * (len(x.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, tree)


def cache_shardings(tree, mesh: Mesh):
    """Decode caches: shard the batch-like dim; stacked caches have a
    leading layer dim. SSM states (B, ...) shard dim 0; KV caches
    (L, B, S, H, dh) shard dim 1. REPRO_CACHE_SHARD=heads disables the
    longest-dim (sequence) fallback — hillclimb knob."""
    import os as _os
    mode = _os.environ.get("REPRO_CACHE_SHARD", "auto")
    present = tuple(n for n in ("pod", "data") if n in mesh.axis_names)
    prod = int(np.prod([mesh.shape[n] for n in present]))

    def leaf(_path, x):
        if not hasattr(x, "shape") or len(x.shape) == 0:
            return NamedSharding(mesh, P())
        spec = [None] * len(x.shape)
        # "heads" mode: never shard a sequence-like dim (dynamic cache
        # slices/updates on a seq-sharded cache cost collective-permutes)
        batch_dims = 1 if mode == "heads" else min(2, len(x.shape))
        for dim in range(batch_dims):
            if x.shape[dim] % prod == 0:
                spec[dim] = present
                break
        else:
            # batch doesn't divide (e.g. long_500k batch=1): shard the
            # longest dim instead (sequence sharding of the KV cache)
            if mode != "heads":
                sizes = [(s, i) for i, s in enumerate(x.shape)]
                s, i = max(sizes)
                if s % prod == 0:
                    spec[i] = present
        # head dim of KV caches (ndim-2) over "model" when divisible
        if "model" in mesh.axis_names and len(x.shape) >= 4:
            hd = len(x.shape) - 2
            if spec[hd] is None and x.shape[hd] % mesh.shape["model"] == 0:
                spec[hd] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, tree)
