"""Render §Dry-run / §Roofline / §Perf markdown from the results JSONs."""
from __future__ import annotations

import argparse
import json


def fmt_e(x):
    return f"{x:9.2e}"


def roofline_table(path="results_roofline.json"):
    rows = [x for x in json.load(open(path)) if x.get("ok")]
    out = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
           "bottleneck | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for x in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        out.append(
            f"| {x['arch']} | {x['shape']} | {x['t_compute']:.3g} | "
            f"{x['t_memory']:.3g} | {x['t_collective']:.3g} | "
            f"{x['bottleneck']} | {x['useful_ratio']:.3f} | "
            f"{x['roofline_fraction']:.4f} |")
    return "\n".join(out)


def dryrun_table(path="results_dryrun.json"):
    rows = [x for x in json.load(open(path)) if x.get("ok")]
    out = ["| arch | shape | mesh | HBM args GB/dev | HBM temp GB/dev | "
           "collectives (counts) | compile s |",
           "|---|---|---|---|---|---|---|"]
    for x in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        mem = x.get("memory", {})
        args = (mem.get("argument_size_in_bytes") or 0) / 2**30
        temp = (mem.get("temp_size_in_bytes") or 0) / 2**30
        counts = x.get("collective_counts", {})
        cstr = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in counts.items() if v)
        out.append(f"| {x['arch']} | {x['shape']} | {x['mesh']} | "
                   f"{args:.2f} | {temp:.2f} | {cstr} | {x.get('compile_s')} |")
    return "\n".join(out)


def hillclimb_table(path="results_hillclimb.json"):
    rows = [x for x in json.load(open(path)) if x.get("ok")]
    out = ["| cell | variant | t_compute s | t_memory s | t_collective s | "
           "bottleneck | roofline frac |",
           "|---|---|---|---|---|---|---|"]
    for x in rows:
        out.append(f"| {x['arch']}×{x['shape']} | {x['variant']} | "
                   f"{x['t_compute']:.3g} | {x['t_memory']:.3g} | "
                   f"{x['t_collective']:.3g} | {x['bottleneck']} | "
                   f"{x['roofline_fraction']:.4f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", choices=["roofline", "dryrun", "hillclimb"],
                    required=True)
    args = ap.parse_args()
    print({"roofline": roofline_table, "dryrun": dryrun_table,
           "hillclimb": hillclimb_table}[args.which]())


if __name__ == "__main__":
    main()
