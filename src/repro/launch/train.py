"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires config → model bundle → optimizer → fault-tolerant TrainLoop over a
mesh (production 16×16 / 2×16×16, or a debug mesh over local devices).
Reduced-size overrides make the same path runnable on one CPU for the
examples and tests; the dry-run covers the full-scale lowering.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.data import SyntheticLMStream
from repro.launch import mesh as mesh_lib
from repro.models import registry as reg
from repro.nn import plan as plan_mod
from repro.optim import adafactor, adamw, warmup_cosine
from repro.train import QATPolicy, TrainLoop, TrainLoopConfig


def parse_plan_arg(arg: str) -> plan_mod.SubstratePlan:
    """CLI plan argument: a spec string, inline plan JSON, or a JSON path."""
    arg = arg.strip()
    if arg.startswith("{"):
        return plan_mod.SubstratePlan.from_json(arg)
    if arg.endswith(".json"):
        return plan_mod.load_plan(arg)
    return plan_mod.as_plan(arg)


def add_reduced_overrides(ap: argparse.ArgumentParser):
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--n-heads", type=int, default=None)
    ap.add_argument("--n-kv-heads", type=int, default=None)
    ap.add_argument("--n-experts", type=int, default=None)
    ap.add_argument("--dot-mode", default=None,
                    help="uniform substrate spec, e.g. 'exact', 'int8', or "
                         "'approx_bitexact:proposed@6' (any registered "
                         "backend:mult@width)")
    ap.add_argument("--dot-plan", default=None,
                    help="site-addressed substrate plan: a spec string, "
                         "inline plan JSON, or path to a plan .json "
                         "(e.g. an autotuner bundle's plan)")


def overrides_from(args) -> dict:
    keys = {"n_layers": args.n_layers, "d_model": args.d_model,
            "d_ff": args.d_ff, "vocab": args.vocab, "n_heads": args.n_heads,
            "n_kv_heads": args.n_kv_heads, "n_experts": args.n_experts}
    out = {k: v for k, v in keys.items() if v is not None}
    # --dot-plan (site-addressed) wins over --dot-mode (uniform shorthand);
    # both land in cfg.dot_plan so any registered arch trains on an
    # approximate substrate without a dedicated config
    if getattr(args, "dot_plan", None):
        out["dot_plan"] = parse_plan_arg(args.dot_plan)
    elif args.dot_mode:
        out["dot_plan"] = plan_mod.SubstratePlan.uniform(
            plan_mod._check_spec(args.dot_mode))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", choices=["none", "debug", "pod", "multipod"],
                    default="none")
    ap.add_argument("--metrics-out", default="")
    ap.add_argument("--qat", action="store_true",
                    help="approximation-aware training: straight-through "
                         "approximate forward on the configured plan")
    ap.add_argument("--qat-forward", choices=["bitexact", "stat"],
                    default="bitexact",
                    help="QAT forward numerics (stat = fast separable "
                         "error-moment model, same wiring+width)")
    ap.add_argument("--qat-moment", action="store_true",
                    help="add the error-moment slope correction to the "
                         "straight-through backward")
    ap.add_argument("--qat-out", default="",
                    help="directory for a final plan+params bundle "
                         "(checkpoint.save_plan_bundle)")
    add_reduced_overrides(ap)
    args = ap.parse_args()

    cfg = reg.get_config(args.arch, **overrides_from(args))
    bundle = reg._BUILDERS[cfg.family](cfg)
    optimizer = adafactor() if cfg.n_experts else adamw()

    qat_policy = (QATPolicy(forward=args.qat_forward,
                            moment_correction=args.qat_moment)
                  if args.qat else None)
    loop = TrainLoop(
        bundle.loss_fn, optimizer,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, lr=args.lr,
                        grad_accum=args.grad_accum,
                        qat=qat_policy, plan=cfg.dot_plan),
        lr_schedule=warmup_cosine(args.lr, max(1, args.steps // 10), args.steps),
    )
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=args.batch,
                               seq_len=args.seq_len, seed=0)

    mesh = None
    if args.mesh == "debug":
        mesh = mesh_lib.make_debug_mesh()
    elif args.mesh == "pod":
        mesh = mesh_lib.make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = mesh_lib.make_production_mesh(multi_pod=True)

    def run():
        params, opt_state, start = loop.init_or_restore(
            lambda: bundle.init_params(jax.random.PRNGKey(0)))
        # read back from loop.cfg: restore may have adopted the checkpoint's
        # plan/policy, and what the loop traces is what should be reported
        qat_tag = (f" qat={loop.cfg.qat.forward}"
                   if loop.cfg.qat is not None else "")
        plan_tag = (f" plan={loop.cfg.plan.label}"
                    if loop.cfg.plan is not None else "")
        print(f"[train] arch={args.arch} start_step={start}{plan_tag}{qat_tag} "
              f"params={sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")
        params, _, _ = loop.run(
            params, opt_state, stream, start,
            on_step=lambda s, l: (s % 10 == 0) and print(
                f"  step {s:5d} loss {l:.4f}", flush=True))
        if args.qat_out:
            from repro import checkpoint as ckpt_lib
            plan = loop.cfg.plan or plan_mod.SubstratePlan.uniform("exact")
            path = ckpt_lib.save_plan_bundle(
                args.qat_out, plan, params,
                extra={"arch": args.arch,
                       "final_loss": loop.metrics.get("final_loss"),
                       "qat": (loop.cfg.qat.describe()
                               if loop.cfg.qat is not None else None)})
            print(f"[train] wrote plan bundle: {path}")

    if mesh is not None:
        with mesh:
            run()
    else:
        run()

    fl = loop.metrics["final_loss"]
    print(f"[train] done: "
          f"final_loss={'n/a' if fl is None else format(fl, '.4f')} "
          f"stragglers={loop.metrics['straggler_steps']} "
          f"resumed_from={loop.metrics['resumed_from']}")
    if args.metrics_out:
        json.dump({k: v for k, v in loop.metrics.items() if k != "losses"} |
                  {"losses_head": loop.metrics["losses"][:5],
                   "losses_tail": loop.metrics["losses"][-5:]},
                  open(args.metrics_out, "w"), indent=1)


if __name__ == "__main__":
    main()
