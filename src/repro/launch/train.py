"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires config → model bundle → optimizer → fault-tolerant TrainLoop over a
mesh (production 16×16 / 2×16×16, or a debug mesh over local devices).
Reduced-size overrides make the same path runnable on one CPU for the
examples and tests; the dry-run covers the full-scale lowering.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.data import SyntheticLMStream
from repro.launch import mesh as mesh_lib
from repro.models import registry as reg
from repro.optim import adafactor, adamw, warmup_cosine
from repro.train import TrainLoop, TrainLoopConfig


def add_reduced_overrides(ap: argparse.ArgumentParser):
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--n-heads", type=int, default=None)
    ap.add_argument("--n-kv-heads", type=int, default=None)
    ap.add_argument("--n-experts", type=int, default=None)
    ap.add_argument("--dot-mode", default=None,
                    choices=["exact", "int8", "approx_stat", "approx_bitexact",
                             "approx_lut"])


def overrides_from(args) -> dict:
    keys = {"n_layers": args.n_layers, "d_model": args.d_model,
            "d_ff": args.d_ff, "vocab": args.vocab, "n_heads": args.n_heads,
            "n_kv_heads": args.n_kv_heads, "n_experts": args.n_experts,
            "dot_mode": args.dot_mode}
    return {k: v for k, v in keys.items() if v is not None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", choices=["none", "debug", "pod", "multipod"],
                    default="none")
    ap.add_argument("--metrics-out", default="")
    add_reduced_overrides(ap)
    args = ap.parse_args()

    cfg = reg.get_config(args.arch, **overrides_from(args))
    bundle = reg._BUILDERS[cfg.family](cfg)
    optimizer = adafactor() if cfg.n_experts else adamw()

    loop = TrainLoop(
        bundle.loss_fn, optimizer,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, lr=args.lr,
                        grad_accum=args.grad_accum),
        lr_schedule=warmup_cosine(args.lr, max(1, args.steps // 10), args.steps),
    )
    stream = SyntheticLMStream(vocab=cfg.vocab, batch=args.batch,
                               seq_len=args.seq_len, seed=0)

    mesh = None
    if args.mesh == "debug":
        mesh = mesh_lib.make_debug_mesh()
    elif args.mesh == "pod":
        mesh = mesh_lib.make_production_mesh(multi_pod=False)
    elif args.mesh == "multipod":
        mesh = mesh_lib.make_production_mesh(multi_pod=True)

    def run():
        params, opt_state, start = loop.init_or_restore(
            lambda: bundle.init_params(jax.random.PRNGKey(0)))
        print(f"[train] arch={args.arch} start_step={start} "
              f"params={sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")
        loop.run(params, opt_state, stream, start,
                 on_step=lambda s, l: (s % 10 == 0) and print(
                     f"  step {s:5d} loss {l:.4f}", flush=True))

    if mesh is not None:
        with mesh:
            run()
    else:
        run()

    print(f"[train] done: final_loss={loop.metrics['final_loss']:.4f} "
          f"stragglers={loop.metrics['straggler_steps']} "
          f"resumed_from={loop.metrics['resumed_from']}")
    if args.metrics_out:
        json.dump({k: v for k, v in loop.metrics.items() if k != "losses"} |
                  {"losses_head": loop.metrics["losses"][:5],
                   "losses_tail": loop.metrics["losses"][-5:]},
                  open(args.metrics_out, "w"), indent=1)


if __name__ == "__main__":
    main()
