import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Scan-aware roofline correction.

XLA's ``cost_analysis`` counts a ``while``-loop body ONCE regardless of trip
count, so the raw dry-run under-reports FLOPs/bytes/collective-bytes for
anything inside (a) the layer scan and (b) the sequence-chunk scans
(attention KV chunks, chunked loss, SSD/mLSTM chunks).

Correction (per single-pod cell):

  * lower the cell with ``cost_unroll=True`` (inner scans fully unrolled —
    every chunk iteration is counted) at TWO layer counts: L0 = 0 layers
    (embed + loss only) and L1 = one scan unit (= the layer-pattern period);
  * per-unit deltas Δ = m(L1) − m(L0) are exact because the unit scan has
    trip count 1;
  * corrected(metric) = m(L0) + (n_layers / period) · Δ.

xlstm / zamba unroll layers in Python already → a single full lowering with
``cost_unroll=True`` is exact (no differencing needed).

Writes results_roofline.json (merging memory_analysis + compile proof from
the raw dry-run results).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.dryrun import make_train_step, pick_optimizer  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models import registry as reg  # noqa: E402

COST_ATTN_CHUNK = 2048
COST_LOSS_CHUNK = 2048


def _metrics_for(cfg, shape, mesh) -> dict:
    """Lower one config at one shape; return flops/bytes/collective bytes."""
    bundle = reg._BUILDERS[cfg.family](cfg)
    with mesh:
        params_sds = reg.param_specs(bundle)
        p_shard = mesh_lib.param_shardings(params_sds, mesh)
        batch_sds = reg.input_specs(cfg, shape)
        b_shard = mesh_lib.batch_shardings(batch_sds, mesh)
        if shape.kind == "train":
            optimizer = pick_optimizer(cfg)
            opt_sds = jax.eval_shape(optimizer.init, params_sds)
            o_shard = mesh_lib.param_shardings(opt_sds, mesh)
            step = make_train_step(bundle, optimizer)
            compiled = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard)) \
                .lower(params_sds, opt_sds, batch_sds).compile()
        elif shape.kind == "prefill":
            compiled = jax.jit(bundle.prefill, in_shardings=(p_shard, b_shard)) \
                .lower(params_sds, batch_sds).compile()
        else:
            state_sds = reg.decode_state_specs(bundle, shape)
            if cfg.family == "encdec":
                state_sds["enc_out"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.n_frames, cfg.d_model), cfg.dtype)
            s_shard = mesh_lib.cache_shardings(state_sds, mesh)
            compiled = jax.jit(bundle.decode_step,
                               in_shardings=(p_shard, s_shard, b_shard)) \
                .lower(params_sds, state_sds, batch_sds).compile()
        cost = roofline.normalize_cost_analysis(compiled.cost_analysis())
        coll = roofline.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
            "coll_by": dict(coll.bytes_by_kind)}


def corrected_cell(arch: str, shape_name: str, dot_mode: str = "exact") -> dict:
    shape = reg.SHAPES[shape_name]
    base_cfg = reg.get_config(arch, dot_mode=dot_mode, cost_unroll=True,
                              attn_chunk=COST_ATTN_CHUNK,
                              loss_chunk=COST_LOSS_CHUNK)
    mesh = mesh_lib.make_production_mesh(multi_pod=False)

    if base_cfg.family in ("xlstm", "zamba"):
        m_full = _metrics_for(base_cfg, shape, mesh)
        return {"flops": m_full["flops"], "bytes": m_full["bytes"],
                "coll": m_full["coll"], "coll_by": m_full["coll_by"],
                "method": "full_unrolled"}

    period = lm.unit_period(base_cfg)
    overrides0 = {"n_layers": 0}
    overrides1 = {"n_layers": period}
    if base_cfg.family == "encdec":
        overrides0["n_encoder_layers"] = 0
        overrides1["n_encoder_layers"] = 1
    cfg0 = reg.get_config(arch, dot_mode=dot_mode, cost_unroll=True,
                          attn_chunk=COST_ATTN_CHUNK,
                          loss_chunk=COST_LOSS_CHUNK, **overrides0)
    cfg1 = reg.get_config(arch, dot_mode=dot_mode, cost_unroll=True,
                          attn_chunk=COST_ATTN_CHUNK,
                          loss_chunk=COST_LOSS_CHUNK, **overrides1)
    m0 = _metrics_for(cfg0, shape, mesh)
    jax.clear_caches()
    m1 = _metrics_for(cfg1, shape, mesh)
    jax.clear_caches()
    scale = base_cfg.n_layers / period
    out = {}
    for key in ("flops", "bytes", "coll"):
        out[key] = m0[key] + scale * (m1[key] - m0[key])
    out["coll_by"] = {k: m0["coll_by"].get(k, 0.0) + scale *
                      (m1["coll_by"].get(k, 0.0) - m0["coll_by"].get(k, 0.0))
                      for k in m1["coll_by"]}
    out["method"] = f"L0+{scale:g}x(L1-L0), period={period}"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--raw", default="results_dryrun.json")
    ap.add_argument("--out", default="results_roofline.json")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()

    raw = [r for r in json.load(open(args.raw))
           if r.get("ok") and r["mesh"] == "16x16"]
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"]) for r in results if r.get("ok")}

    for cell in raw:
        arch, shape_name = cell["arch"], cell["shape"]
        if args.only_arch and arch != args.only_arch:
            continue
        if (arch, shape_name) in done:
            continue
        print(f"[rooffix] {arch} × {shape_name} ...", flush=True)
        t0 = time.time()
        try:
            corr = corrected_cell(arch, shape_name)
            cfg = reg.get_config(arch)
            shape = reg.SHAPES[shape_name]
            rf = roofline.Roofline(
                flops_per_device=corr["flops"],
                bytes_per_device=corr["bytes"],
                collective_bytes=corr["coll"],
                n_devices=cell["n_devices"],
                model_flops=roofline.model_flops_for(
                    cfg, shape, n_active=cell["active_params"]),
            )
            merged = dict(cell)
            merged.update(
                flops_per_device=corr["flops"], bytes_per_device=corr["bytes"],
                collective_bytes=corr["coll"],
                collective_breakdown=corr["coll_by"],
                correction=corr["method"], fix_s=round(time.time() - t0, 1),
                **rf.row(),
            )
            merged["ok"] = True
            print(f"  ok ({merged['fix_s']}s): bottleneck={rf.bottleneck} "
                  f"useful={rf.useful_flops_ratio:.3f} "
                  f"rooffrac={rf.roofline_fraction:.4f}", flush=True)
        except Exception as e:  # noqa: BLE001
            merged = dict(arch=arch, shape=shape_name, ok=False,
                          error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-1500:])
            print(f"  FAIL: {merged['error']}", flush=True)
        results.append(merged)
        json.dump(results, open(args.out, "w"), indent=1, default=str)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} corrected")


if __name__ == "__main__":
    main()
