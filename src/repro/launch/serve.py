"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the batched serving engine with synthetic requests (reduced configs on
CPU; full-scale serving graphs are exercised by the dry-run's prefill /
decode lowering).

Telemetry: ``--metrics-out`` dumps the engine's metrics registry
(Prometheus text for ``.prom``/``.txt`` paths, JSON otherwise) and
``--trace-out`` writes a Chrome/Perfetto trace of the serving spans —
load it at ``ui.perfetto.dev``. See ``docs/observability.md``.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.launch.train import add_reduced_overrides, overrides_from
from repro.models import registry as reg
from repro.obs import Tracer, tracing_scope, write_chrome_trace, write_metrics
from repro.serving import ServingEngine
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=reg.list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--workers", type=int, default=1,
                    help="concurrent decode loops (each with its own KV "
                         "caches; requests split round-robin)")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="substrate plan: a plan JSON file or a plan-bundle "
                         "directory (see docs/plans.md). Serves the model "
                         "with per-site mixed substrates; a bundle that "
                         "carries params restores them too.")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump serving metrics (.prom/.txt → Prometheus "
                         "text, else JSON)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "serving spans")
    add_reduced_overrides(ap)
    args = ap.parse_args()

    cfg = reg.get_config(args.arch, **overrides_from(args))
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    plan = None
    if args.plan:
        from repro import checkpoint as ckpt
        from repro.nn import plan as plan_mod

        if os.path.isdir(args.plan):
            plan, raw, _ = ckpt.load_plan_bundle(args.plan)
            if raw is not None:   # bundle ships params: restore into our tree
                _, params, _ = ckpt.load_plan_bundle(
                    args.plan, params_template=params)
        else:
            plan = plan_mod.load_plan(args.plan)
        print(f"[serve] substrate plan: {plan.label}")
    engine = ServingEngine(bundle, params, batch_size=args.batch,
                           max_len=args.max_len, substrate=plan)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab, size=4)),
                    max_tokens=args.max_tokens,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(args.requests)]
    tracer = Tracer() if args.trace_out else None
    t0 = time.perf_counter()
    with tracing_scope(tracer):
        out = engine.generate(reqs, workers=args.workers)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in out)
    for i, r in enumerate(out):
        print(f"req{i}: prompt={r.prompt} -> {r.output}")
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    if args.metrics_out:
        p = write_metrics(engine.metrics.registry, args.metrics_out)
        print(f"[serve] metrics -> {p}")
    if args.trace_out:
        p = write_chrome_trace(tracer, args.trace_out)
        print(f"[serve] trace -> {p} ({len(tracer.events())} events)")


if __name__ == "__main__":
    main()
