"""Energy/quality substrate-plan autotuner (§Per-layer assignments).

Searches per-site substrate assignments (:class:`repro.nn.plan.SubstratePlan`)
that minimize estimated MAC energy — MACs × the wiring's per-op PDP from the
unit-gate model (``repro.core.energy``) — subject to a quality budget:

* **edge workload** — PSNR of the planned Laplacian edge maps
  (``conv.edge.center`` / ``conv.edge.ring`` tap-group sites) against the
  exact-multiplier reference, the paper's Fig. 9 metric;
* **lm workload** — max-abs logit divergence of a (reduced) LM prefill
  against the exact substrate, with per-layer ``layer.<i>.*`` move patterns.

Search is greedy: starting from a uniform baseline plan, repeatedly apply the
single (site → spec) move with the lowest estimated PDP among those whose
*scored* quality stays within budget, until no move lowers PDP. Scoring runs
on the fast ``approx_stat`` counterpart of each candidate backend (the
statistical error model — no per-product LUT work); the winning plan is then
re-validated on the bit-exact backends, walking back through accepted moves
if the final check fails (stat scoring is a ranking heuristic, not an
oracle).

Per-site MAC counts come from one metered run (``obs.meter``) of the
baseline plan — move sets never change a site's contraction shape, so the
measurement is reused across the whole search.

The result is written as a loadable plan bundle
(``checkpoint.save_plan_bundle``): serve it with
``python -m repro.launch.serve --plan <dir>`` or
``EdgeDetectService(substrate=plan)``.

  python -m repro.launch.autotune --workload edge --out runs/edge_plan \\
      --wirings proposed,design_du2022 --widths 6,7,8 --images 6 --size 64x64
"""
from __future__ import annotations

import argparse
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.nn import plan as plan_mod
from repro.nn import substrate as psub
from repro.obs.meter import ContractionMeter, pdp_per_mac_fj, telemetry_scope

# fast statistical scoring counterparts — canonical home is nn.plan (the
# QAT layer shares them); re-exported here for existing callers
stat_spec = plan_mod.stat_spec
stat_plan = plan_mod.stat_plan


def with_rule(plan: plan_mod.SubstratePlan, pattern: str,
              spec: str) -> plan_mod.SubstratePlan:
    """``plan`` with ``pattern`` (re)assigned to ``spec``.

    An existing rule for the identical pattern is dropped and the new rule
    appended last; other rules are kept (exact-site rules still out-rank
    glob rules by the plan's specificity ordering).
    """
    rules = tuple((p, s) for p, s in plan.rules if p != pattern)
    return plan_mod.SubstratePlan(default=plan.default,
                                  rules=rules + ((pattern, spec),))


def measure_site_macs(run_fn: Callable[[plan_mod.SubstratePlan], None],
                      plan: plan_mod.SubstratePlan) -> Dict[str, int]:
    """Per-site MAC counts from one metered execution of ``run_fn(plan)``."""
    meter = ContractionMeter()
    with telemetry_scope(meter):
        run_fn(plan)
    return {site: int(e["macs"])
            for site, e in meter.site_summary().items() if e["macs"]}


def plan_pdp_fj(site_macs: Dict[str, int],
                plan: plan_mod.SubstratePlan) -> float:
    """Estimated energy (fJ) of the measured workload under ``plan``.

    Each measured site is priced at MACs × the per-op PDP of the multiplier
    its resolved spec names (``exact`` designs — including ``int8``'s exact
    8×8 array — price at the exact row of Table 5).
    """
    total = 0.0
    for site, macs in site_macs.items():
        meta = psub.get_substrate(plan.resolve(site)).meta
        total += macs * pdp_per_mac_fj(meta.mult_key)
    return total


def greedy_minimize(plan0: plan_mod.SubstratePlan,
                    patterns: Sequence[str], candidates: Sequence[str],
                    evaluate: Callable[[plan_mod.SubstratePlan],
                                       Tuple[float, float]],
                    budget: float,
                    log: Callable[[str], None] = lambda s: None):
    """Greedy PDP descent over single (pattern → spec) moves.

    ``evaluate(plan) -> (pdp_fj, score)`` prices and scores a candidate
    plan (higher scores are better). Accepts, per round, the move with the
    lowest estimated PDP among those whose score stays ≥ ``budget``; stops
    when no move lowers PDP. Returns ``(plan, pdp_fj, history)`` where
    ``history`` records every accepted step (including the starting point)
    for validation-time rollback.
    """
    cur = plan0
    cur_pdp, cur_score = evaluate(cur)
    history = [{"pattern": None, "spec": None, "pdp_fj": cur_pdp,
                "score": cur_score, "plan": cur.to_dict()}]
    while True:
        best = None  # (pdp, pattern, spec, score, plan)
        for pattern in patterns:
            for spec in candidates:
                if cur.resolve(pattern) == spec:
                    continue  # no-op move
                trial = with_rule(cur, pattern, spec)
                pdp, score = evaluate(trial)
                log(f"  try {pattern} -> {spec}: pdp={pdp:.1f} fJ "
                    f"score={score:.3f} "
                    f"({'ok' if score >= budget else 'reject'})")
                if pdp >= cur_pdp or score < budget:
                    continue
                if best is None or pdp < best[0]:
                    best = (pdp, pattern, spec, score, trial)
        if best is None:
            return cur, cur_pdp, history
        cur_pdp, pattern, spec, score, cur = best
        log(f"[autotune] accept {pattern} -> {spec} "
            f"(pdp={cur_pdp:.1f} fJ, score={score:.3f})")
        history.append({"pattern": pattern, "spec": spec, "pdp_fj": cur_pdp,
                        "score": score, "plan": cur.to_dict()})


def _validate_with_rollback(history: List[dict],
                            validate_fn: Callable[[plan_mod.SubstratePlan],
                                                  Tuple[bool, float, float]],
                            log: Callable[[str], None] = lambda s: None):
    """Walk accepted plans newest-first until one passes bit-exact validation.

    ``validate_fn(plan) -> (ok, quality, pdp_fj)``. Returns
    ``(plan, pdp_fj, quality, n_rolled_back)``; the baseline (first history
    entry) always terminates the walk — by construction it passes the
    match-mode budget, and an explicit floor the baseline itself misses is
    reported as-is rather than silently widened.
    """
    for i, step in enumerate(reversed(history)):
        plan = plan_mod.SubstratePlan.from_dict(step["plan"])
        ok, quality, pdp = validate_fn(plan)
        if ok or i == len(history) - 1:
            if i:
                log(f"[autotune] rolled back {i} step(s) at validation")
            return plan, pdp, quality, i
    raise AssertionError("unreachable: baseline terminates the walk")


# ---------------------------------------------------------------------------
# edge workload
# ---------------------------------------------------------------------------


def autotune_edge(images: Optional[np.ndarray] = None, *,
                  wirings: Sequence[str] = ("proposed", "design_du2022"),
                  widths: Sequence[int] = (6, 7, 8),
                  baseline: str = "approx_bitexact:proposed@8",
                  psnr_floor: Optional[float] = None,
                  n_images: int = 6, size: Tuple[int, int] = (64, 64),
                  seed: int = 0, verbose: bool = False,
                  qat_steps: int = 0, qat_lr: float = 0.05) -> dict:
    """Tune per-tap-group substrates for the edge-detection workload.

    Quality metric: PSNR of the planned edge maps against the exact
    multiplier's, over ``images`` (a (B, H, W) uint8 batch; a procedural
    ``data.image_batch`` when omitted). ``psnr_floor=None`` is match mode:
    the budget is the baseline's own scored PSNR, so the tuned plan must be
    estimated no worse than uniform ``baseline`` — and is finally
    *validated* no worse on the bit-exact backends. Widths are capped at 8:
    the planned tap-group sum is only distributive for left-shift rescales
    (see :func:`repro.nn.conv.edge_detect_planned`).

    ``qat_steps > 0`` makes the search *approximation-aware*: every
    candidate plan (and the final validation) is scored on the PSNR after a
    ``qat_steps``-step :func:`repro.train.qat.finetune_edge` recovery under
    that plan's wirings, so greedy accepts moves whose error the model can
    train away — cheaper plans become reachable that raw scoring rejects.
    QAT widths are floored at 5 (the quantizer-clip contract of
    :func:`repro.train.qat.edge_response`); the adapted edge params ride
    along in the result (and hence the saved bundle).

    Returns a result dict (see the CLI) with the winning plan under
    ``"plan"``.
    """
    from repro.data import image_batch
    from repro.nn import conv

    if max(widths) > 8:
        raise ValueError(f"edge plan widths must be <= 8, got {tuple(widths)}")
    if images is None:
        h, w = size
        images = image_batch(n_images, h, w, seed=seed)
    images = np.asarray(images, np.uint8)
    log = print if verbose else (lambda s: None)

    ref = np.asarray(conv.edge_detect_batched(images, "exact"))
    base_plan = plan_mod.SubstratePlan.uniform(baseline)
    sites = conv.edge_tap_sites()
    site_macs = measure_site_macs(
        lambda p: np.asarray(conv.edge_detect_planned(images, p)), base_plan)

    if qat_steps and min(widths) < 5:
        raise ValueError(
            f"qat_steps > 0 needs widths >= 5, got {tuple(widths)}")

    def _finetuned(plan):
        from repro.train import qat as qat_mod
        return qat_mod.finetune_edge(images, plan, steps=qat_steps,
                                     lr=qat_lr)

    def evaluate(plan):
        if qat_steps:
            # adapted quality: PSNR after a short QAT recovery on the fast
            # stat counterpart of the candidate's wirings
            score = _finetuned(stat_plan(plan))["psnr_post"]
        else:
            score = conv.psnr(
                ref, conv.edge_detect_planned(images, stat_plan(plan)))
        return plan_pdp_fj(site_macs, plan), score

    def exact_psnr(plan):
        if qat_steps:
            return _finetuned(plan)["psnr_post"]
        return conv.psnr(ref, conv.edge_detect_planned(images, plan))

    budget = (evaluate(base_plan)[1] if psnr_floor is None
              else float(psnr_floor))
    log(f"[autotune] edge: budget (scored PSNR) = {budget:.3f} dB")
    candidates = [f"approx_bitexact:{w}@{n}" for w in wirings for n in widths]
    tuned, tuned_pdp, history = greedy_minimize(
        base_plan, sites, candidates, evaluate, budget, log=log)

    base_psnr = exact_psnr(base_plan)
    floor = base_psnr if psnr_floor is None else float(psnr_floor)

    def validate(plan):
        q = exact_psnr(plan)
        return q >= floor, q, plan_pdp_fj(site_macs, plan)

    tuned, tuned_pdp, tuned_psnr, rolled_back = _validate_with_rollback(
        history, validate, log=log)
    res = {
        "workload": "edge",
        "sites": list(sites),
        "site_macs": site_macs,
        "candidates": candidates,
        "budget_scored_db": budget,
        "baseline": {"plan": base_plan.to_dict(), "psnr_db": base_psnr,
                     "pdp_fj": plan_pdp_fj(site_macs, base_plan)},
        "tuned": {"plan": tuned.to_dict(), "psnr_db": tuned_psnr,
                  "pdp_fj": tuned_pdp},
        "history": history,
        "rolled_back": rolled_back,
        "plan": tuned,
    }
    if qat_steps:
        fin = _finetuned(tuned)
        res["qat"] = {"steps": int(qat_steps), "lr": float(qat_lr),
                      "psnr_pre": fin["psnr_pre"],
                      "psnr_post": fin["psnr_post"]}
        res["params"] = fin["params"]  # adapted edge params → bundle
    return res


# ---------------------------------------------------------------------------
# lm workload
# ---------------------------------------------------------------------------


def autotune_lm(arch: str, *, overrides: Optional[dict] = None,
                candidates: Sequence[str] = ("int8",
                                             "approx_bitexact:proposed@8"),
                baseline: str = "exact",
                div_budget: float = 0.25,
                batch: int = 2, seq: int = 16, seed: int = 0,
                verbose: bool = False) -> dict:
    """Tune per-layer substrates for a (reduced) LM prefill.

    Quality metric: max-abs logit divergence against the exact substrate on
    a fixed synthetic token batch — the tuned plan must stay within
    ``div_budget`` both under ``approx_stat`` scoring and in the final
    bit-exact validation. Move patterns are per-layer globs
    (``layer.<i>.*``), so one move reassigns a whole layer's denses.

    PDP is *measured*, not modeled: every trial runs once under the
    ambient :class:`~repro.obs.meter.ContractionMeter`, whose energy
    counters price each executed contraction by its substrate's multiplier
    at execution time — attribution stays exact even where the scan
    dispatch condenses site labels across stacked layers. The same run
    yields the divergence, so one prefill per trial covers both numbers.

    Returns the same result-dict shape as :func:`autotune_edge`, plus the
    ``params`` used (callers bundle them for serving round-trips).
    """
    import jax

    from repro.models import registry as reg

    overrides = dict(overrides or {})
    log = print if verbose else (lambda s: None)
    cfg = reg.get_config(arch, **overrides)
    exact_bundle = reg.get_bundle(arch, dot_plan="exact", **overrides)
    params = exact_bundle.init_params(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    tokens = {"tokens": rng.integers(1, cfg.vocab, size=(batch, seq))}
    ref = np.asarray(exact_bundle.prefill(params, tokens), np.float32)

    def metered(plan):
        """One metered prefill → (measured pdp_fj, max-abs divergence)."""
        meter = ContractionMeter()
        b = reg.get_bundle(arch, dot_plan=plan, **overrides)
        with telemetry_scope(meter):
            out = np.asarray(b.prefill(params, tokens), np.float32)
        pdp = sum(e["energy_pdp_fj"] for e in meter.summary().values())
        return pdp, float(np.abs(out - ref).max())

    base_plan = plan_mod.SubstratePlan.uniform(baseline)
    site_macs = measure_site_macs(
        lambda p: np.asarray(
            reg.get_bundle(arch, dot_plan=p, **overrides).prefill(
                params, tokens)), base_plan)
    patterns = [f"layer.{i}.*" for i in range(cfg.n_layers)]
    # scores are negated divergences so "higher is better" matches greedy's
    # contract; the budget is the negated divergence allowance
    budget = -float(div_budget)

    def evaluate(plan):
        pdp, div = metered(stat_plan(plan))
        return pdp, -div

    def validate(plan):
        pdp, div = metered(plan)
        return div <= float(div_budget), div, pdp

    tuned, tuned_pdp, history = greedy_minimize(
        base_plan, patterns, list(candidates), evaluate, budget, log=log)
    tuned, tuned_pdp, tuned_div, rolled_back = _validate_with_rollback(
        history, validate, log=log)
    base_pdp, base_div = metered(base_plan)
    return {
        "workload": "lm",
        "arch": arch,
        "sites": patterns,
        "site_macs": site_macs,
        "candidates": list(candidates),
        "div_budget": float(div_budget),
        "baseline": {"plan": base_plan.to_dict(), "divergence": base_div,
                     "pdp_fj": base_pdp},
        "tuned": {"plan": tuned.to_dict(), "divergence": tuned_div,
                  "pdp_fj": tuned_pdp},
        "history": history,
        "rolled_back": rolled_back,
        "plan": tuned,
        "params": params,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _result_summary(res: dict) -> dict:
    """The JSON-serializable slice of a result (drops params / plan object)."""
    return {k: v for k, v in res.items() if k not in ("plan", "params")}


def main(argv: Optional[Sequence[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", choices=["edge", "lm"], default="edge")
    ap.add_argument("--out", required=True, metavar="DIR",
                    help="plan-bundle output directory (loadable by "
                         "launch/serve --plan and EdgeDetectService)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the full search record as JSON")
    ap.add_argument("--baseline", default=None,
                    help="uniform starting spec (default: "
                         "approx_bitexact:proposed@8 for edge, exact for lm)")
    ap.add_argument("--seed", type=int, default=0)
    # edge knobs
    ap.add_argument("--wirings", default="proposed,design_du2022",
                    help="comma-separated wiring names to search (edge)")
    ap.add_argument("--widths", default="6,7,8",
                    help="comma-separated operand widths <= 8 (edge)")
    ap.add_argument("--images", type=int, default=6,
                    help="procedural image count (edge)")
    ap.add_argument("--size", default="64x64", metavar="HxW",
                    help="procedural image shape (edge)")
    ap.add_argument("--psnr-floor", type=float, default=None,
                    help="explicit PSNR budget in dB (edge; default: match "
                         "the baseline plan's own PSNR)")
    ap.add_argument("--qat-steps", type=int, default=0,
                    help="approximation-aware search: score each candidate "
                         "plan after this many QAT fine-tune steps (edge; "
                         "0 = raw scoring)")
    ap.add_argument("--qat-lr", type=float, default=0.05,
                    help="learning rate for --qat-steps fine-tuning (edge)")
    # lm knobs
    ap.add_argument("--arch", default=None, help="registry arch id (lm)")
    ap.add_argument("--candidates", default="int8,approx_bitexact:proposed@8",
                    help="comma-separated candidate specs (lm)")
    ap.add_argument("--div-budget", type=float, default=0.25,
                    help="max-abs logit divergence allowance (lm)")
    ap.add_argument("--n-layers", type=int, default=None,
                    help="reduced layer count override (lm)")
    args = ap.parse_args(argv)

    from repro import checkpoint as ckpt

    if args.workload == "edge":
        h, w = (int(v) for v in args.size.lower().split("x"))
        res = autotune_edge(
            wirings=tuple(args.wirings.split(",")),
            widths=tuple(int(v) for v in args.widths.split(",")),
            baseline=args.baseline or "approx_bitexact:proposed@8",
            psnr_floor=args.psnr_floor, n_images=args.images, size=(h, w),
            seed=args.seed, verbose=True,
            qat_steps=args.qat_steps, qat_lr=args.qat_lr)
        quality = ("psnr_db", "dB")
        if "qat" in res:
            print(f"[autotune] qat({res['qat']['steps']} steps): "
                  f"pre={res['qat']['psnr_pre']:.3f} dB -> "
                  f"post={res['qat']['psnr_post']:.3f} dB (tuned plan)")
    else:
        if not args.arch:
            ap.error("--workload lm requires --arch")
        overrides = {}
        if args.n_layers is not None:
            overrides["n_layers"] = args.n_layers
        res = autotune_lm(
            args.arch, overrides=overrides,
            candidates=tuple(args.candidates.split(",")),
            baseline=args.baseline or "exact",
            div_budget=args.div_budget, seed=args.seed, verbose=True)
        quality = ("divergence", "")

    base, tuned = res["baseline"], res["tuned"]
    qk, unit = quality
    print(f"[autotune] baseline: pdp={base['pdp_fj']:.1f} fJ "
          f"{qk}={base[qk]:.3f} {unit}")
    print(f"[autotune] tuned:    pdp={tuned['pdp_fj']:.1f} fJ "
          f"{qk}={tuned[qk]:.3f} {unit} "
          f"({100 * (1 - tuned['pdp_fj'] / base['pdp_fj']):.1f}% energy saved)")
    for pattern, spec in res["plan"].rules:
        print(f"  {pattern} -> {spec}")

    path = ckpt.save_plan_bundle(
        args.out, res["plan"], params=res.get("params"),
        extra={"autotune": _result_summary(res)})
    print(f"[autotune] bundle -> {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(_result_summary(res), f, indent=1, default=str)
        print(f"[autotune] record -> {args.json}")
    return res


if __name__ == "__main__":
    main()
