import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import — jax locks the device
count on first init. 512 placeholder host devices back the production
meshes: 16×16 single-pod and 2×16×16 multi-pod.

Per cell:
  * build the model bundle and ShapeDtypeStruct inputs/params (no alloc),
  * jit the step (train_step = loss+grad+optimizer; serve = prefill or
    decode_step) with explicit FSDP+TP in_shardings,
  * ``.lower().compile()`` — sharding mismatches / OOM / unsupported
    collectives fail HERE, which is the point of the dry-run,
  * record memory_analysis(), cost_analysis(), and the parsed collective
    byte totals into a JSON results file for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k \
      [--multi-pod] [--out results.json] [--dot-mode exact] [--dot-partition]
  python -m repro.launch.dryrun --all [--out results.json]

--dot-partition lowers every dense() contraction through the substrate
layer's shard_map Partitioning (data-parallel M over "data",
reduce-scattered K over "model") — the mesh path for the approx substrates.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.models import registry as reg
from repro.optim import adafactor, adamw


def make_train_step(bundle: reg.ModelBundle, optimizer, accum: int = 1):
    """Train step with microbatched gradient accumulation.

    accum > 1 bounds peak activation/residual memory to a 1/accum slice of
    the global batch — the production answer to 1M-token global batches on
    16 GB chips (the full-batch variant is what rooffix measures, since the
    two have identical total FLOPs/bytes/collectives per step).
    """
    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(bundle.loss_fn)(params, batch)
        else:
            def micro(i, carry):
                acc_loss, acc_grads = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum), x.shape[0] // accum,
                        axis=0) if getattr(x, "ndim", 0) else x, batch)
                l, g = jax.value_and_grad(bundle.loss_fn)(params, mb)
                return (acc_loss + l / accum,
                        jax.tree_util.tree_map(
                            lambda a, b: (a.astype(jnp.float32)
                                          + b.astype(jnp.float32) / accum
                                          ).astype(a.dtype), acc_grads, g))
            # bf16 gradient accumulation: halves the persistent accum
            # buffer for trillion-param configs (production trade-off)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            loss, grads = jax.lax.fori_loop(
                0, accum, micro, (jnp.zeros((), jnp.float32), zeros))
        new_params, new_state = optimizer.update(grads, opt_state, params,
                                                 lr=jnp.float32(1e-4))
        return loss, new_params, new_state
    return train_step


def pick_optimizer(cfg):
    # factored moments for the trillion-parameter MoE configs
    return adafactor() if cfg.n_experts else adamw()


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               dot_mode: str = "exact", donate: bool = True,
               dot_partition: bool = False) -> Dict[str, Any]:
    from repro.nn import substrate as psub

    shape = reg.SHAPES[shape_name]
    cfg = reg.get_config(arch, dot_mode=dot_mode)
    bundle = reg._BUILDERS[cfg.family](cfg)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    # --dot-partition: every dense() contraction lowers through shard_map
    # (data-parallel M, reduce-scattered K) instead of leaving GSPMD to
    # shard the substrate's scalar-emulation HLO — this is what lets
    # approx_stat / approx_pallas dot modes ride the production mesh
    part = mesh_lib.contraction_partitioning(mesh) if dot_partition else None

    t0 = time.time()
    with mesh, psub.partitioning_scope(part):
        params_sds = reg.param_specs(bundle)
        import numpy as _np
        measured = sum(int(_np.prod(l.shape))
                       for l in jax.tree_util.tree_leaves(params_sds))
        # measured active params: measured total minus the formula's
        # (total − active) expert surplus
        n_active = measured - (cfg.param_count() - cfg.active_param_count())
        p_shard = mesh_lib.param_shardings(params_sds, mesh)
        batch_sds = reg.input_specs(cfg, shape)
        b_shard = mesh_lib.batch_shardings(batch_sds, mesh)

        if shape.kind == "train":
            optimizer = pick_optimizer(cfg)
            opt_sds = jax.eval_shape(optimizer.init, params_sds)
            o_shard = mesh_lib.param_shardings(opt_sds, mesh)
            # microbatch the 1M-token global batch: peak residuals fit HBM
            # (trillion-param MoE configs need deeper accumulation)
            accum = 1
            for cand in (32, 16, 8):
                if shape.global_batch % cand == 0 and \
                        shape.global_batch // cand >= 8:
                    accum = cand if cfg.param_count() > 3e11 else 8
                    break
            step = make_train_step(bundle, optimizer, accum=accum)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            jitted = jax.jit(bundle.prefill, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            state_sds = reg.decode_state_specs(bundle, shape)
            if cfg.family == "encdec":
                state_sds["enc_out"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.n_frames, cfg.d_model), cfg.dtype)
            s_shard = mesh_lib.cache_shardings(state_sds, mesh)
            jitted = jax.jit(
                bundle.decode_step,
                in_shardings=(p_shard, s_shard, b_shard),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_sds, state_sds, batch_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()

    rf = roofline.derive(cost, hlo, n_dev,
                          roofline.model_flops_for(cfg, shape, n_active=n_active))
    coll = roofline.parse_collectives(hlo)
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    result = dict(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=n_dev, kind=shape.kind, dot_mode=dot_mode,
        dot_partition=dot_partition,
        params=measured, active_params=n_active,
        flops_per_device=rf.flops_per_device,
        bytes_per_device=rf.bytes_per_device,
        collective_bytes=rf.collective_bytes,
        collective_breakdown=coll.bytes_by_kind,
        collective_counts=coll.count_by_kind,
        model_flops=rf.model_flops,
        memory=mem_fields,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        **rf.row(),
    )
    return result


def run_cells(cells, out_path: str, dot_mode: str = "exact",
              dot_partition: bool = False):
    results = []
    if out_path and os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"], r["mesh"], r.get("dot_mode", "exact"),
             r.get("dot_partition", False))
            for r in results if r.get("ok", True)}
    for arch, shape_name, multi_pod in cells:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        key = (arch, shape_name, mesh_name, dot_mode, dot_partition)
        if key in done:
            print(f"[skip] {key}")
            continue
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name} ...", flush=True)
        try:
            r = lower_cell(arch, shape_name, multi_pod, dot_mode=dot_mode,
                           dot_partition=dot_partition)
            r["ok"] = True
            print(f"  ok: flops/dev={r['flops_per_device']:.3e} "
                  f"coll={r['collective_bytes']:.3e}B "
                  f"bottleneck={r['bottleneck']} "
                  f"compile={r['compile_s']}s", flush=True)
        except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
            r = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                     dot_mode=dot_mode, ok=False, error=f"{type(e).__name__}: {e}",
                     traceback=traceback.format_exc()[-2000:])
            print(f"  FAIL: {r['error']}", flush=True)
        results.append(r)
        if out_path:
            json.dump(results, open(out_path, "w"), indent=1, default=str)
        jax.clear_caches()  # keep the long sweep's RSS bounded
    return results


def all_cells(multi_pod: bool):
    cells = []
    for arch in reg.list_archs():
        if arch == "edge-detect":
            continue
        for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape_name == "long_500k" and arch not in reg.SUBQUADRATIC:
                continue
            cells.append((arch, shape_name, multi_pod))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k", choices=list(reg.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every (arch × shape)")
    ap.add_argument("--out", default="")
    ap.add_argument("--dot-mode", default="exact")
    ap.add_argument("--dot-partition", action="store_true",
                    help="lower substrate contractions through shard_map "
                         "(data-parallel M over 'data', reduce-scattered K "
                         "over 'model') instead of GSPMD auto-sharding")
    args = ap.parse_args()

    if args.all:
        cells = all_cells(multi_pod=args.multi_pod)
        if args.both_meshes:
            cells = all_cells(False) + all_cells(True)
    else:
        assert args.arch, "--arch required unless --all"
        cells = [(args.arch, args.shape, args.multi_pod)]
        if args.both_meshes:
            cells = [(args.arch, args.shape, False), (args.arch, args.shape, True)]
    results = run_cells(cells, args.out, dot_mode=args.dot_mode,
                        dot_partition=args.dot_partition)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} cells ok")
    if not args.out:
        print(json.dumps(results[-1], indent=2, default=str))


if __name__ == "__main__":
    main()
