"""Substrate meters: runtime per-contraction energy/error accounting.

The paper's headline numbers — PDP and power savings, bounded edge-
detection error — are *observable quantities*; this module makes them
observable at runtime instead of only in offline estimates. An ambient
:class:`ContractionMeter` (installed with :func:`telemetry_scope`,
mirroring ``repro.nn.substrate.partitioning_scope``) makes every
``ProductSubstrate.dot_general`` call — and the fused conv path in
``repro.nn.conv`` — record, per ``(spec, site)``:

* **contraction counts** and **MACs** (``b·m·k·n`` scalar products);
* **estimated energy** as MACs × the wiring's per-operation PDP from the
  unit-gate model (``repro.core.energy.estimate``), in fJ — the runtime
  counterpart of the offline Table-5 numbers;
* optionally (``error_probe=True``) **online error moments**: a small
  random row-slab of the contraction re-runs per-product against the
  exact multiplier and the signed mean error, MED (mean |error|) and
  max-ED accumulate per site — runtime PDP-vs-quality accounting.

Execution-time semantics under ``jax.jit``: the substrate hooks record
through ``jax.debug.callback``, which is retained in compiled functions
and fires on *every execution* (and immediately in eager mode) — a jitted
serving step traced once still counts every batch it serves. The callback
consults :func:`current_meter` at fire time, so a compiled function traced
with a scope active records nothing once the scope exits.

Overhead contract: with no scope active the hooks cost one global read
per ``dot_general`` and touch no registry; outputs are bit-identical
either way (metering is purely additive — the probe computes a side
comparison, never perturbs the contraction).

The scope is installed *process-wide*, not thread-local: serving
contractions run on batcher worker threads (and ``jax.debug.callback``
may fire from runtime threads), none of which would see the installing
thread's locals. Install from one place at a time.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core import multiplier as mult
from repro.obs.registry import MetricsRegistry

__all__ = ["ContractionMeter", "telemetry_scope", "current_meter",
           "pdp_per_mac_fj"]


@functools.lru_cache(maxsize=None)
def pdp_per_mac_fj(mult_key: str) -> float:
    """Estimated energy per scalar product (fJ) for ``"name[@N]"``.

    Priced through the unit-gate model: one MAC's multiplier operation
    costs the design's PDP (power × delay ≈ energy/op) at its width.
    Aliases and the implicit ``@8`` resolve through the canonical key, so
    every spec naming the same hardware design prices identically.
    Designs the energy model doesn't know (none today) price as 0.
    """
    base, n = mult.split_width(mult.canonical_key(mult_key))
    try:
        return float(energy.estimate(base, n)["pdp"])
    except KeyError:
        return 0.0


def _record_cb(payload) -> None:
    """Execution-time contraction record; consults the *current* scope."""
    m = current_meter()
    if m is not None:
        m._record_contraction(*payload)


def _probe_cb(spec: str, site: str, n_products: int,
              sum_err, sum_abs_err, max_ed) -> None:
    m = current_meter()
    if m is not None:
        m._record_probe(spec, site, n_products, float(sum_err),
                        float(sum_abs_err), float(max_ed))


class ContractionMeter:
    """Per-(spec, site) contraction/energy/error accounting into a registry.

    registry:    shared :class:`~repro.obs.registry.MetricsRegistry` (a
                 private one is created when omitted) — export with
                 ``meter.registry.to_prometheus()`` / ``.to_json()``.
    error_probe: opt in to the online error probe (adds a per-product
                 side comparison on a sampled slab of every metered
                 contraction — measurable overhead, off by default).
    probe_rows / probe_cols / probe_k:
                 slab caps: at most ``rows × k × cols`` products are
                 re-run per contraction (rows are sampled at random from
                 the lhs free dim; k and cols truncate).
    seed:        seed for the row-sampling RNG (trace-time, host-side).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None, *,
                 error_probe: bool = False, probe_rows: int = 4,
                 probe_cols: int = 8, probe_k: int = 1024, seed: int = 0):
        if min(probe_rows, probe_cols, probe_k) < 1:
            raise ValueError("probe slab caps must be >= 1")
        self.registry = registry if registry is not None else MetricsRegistry()
        self.error_probe = bool(error_probe)
        self.probe_rows = int(probe_rows)
        self.probe_cols = int(probe_cols)
        self.probe_k = int(probe_k)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        r = self.registry
        labels = ("spec", "site")
        self._contractions = r.counter(
            "substrate_contractions_total",
            "dot_general contractions executed", labels)
        self._macs = r.counter(
            "substrate_macs_total",
            "scalar products (b*m*k*n) contracted", labels)
        self._energy = r.counter(
            "substrate_energy_pdp_fj_total",
            "estimated energy: MACs x per-op PDP (unit-gate model), fJ",
            labels)
        self._probe_n = r.counter(
            "substrate_probe_products_total",
            "scalar products re-run against the exact multiplier", labels)
        self._probe_err = r.gauge(
            "substrate_probe_err_sum",
            "signed error sum (approx - exact) over probed products", labels)
        self._probe_abs = r.counter(
            "substrate_probe_abs_err_sum",
            "absolute error sum over probed products", labels)
        self._probe_max = r.gauge(
            "substrate_probe_max_ed",
            "max error distance seen by the probe", labels)

    # -- recording core (called from jax.debug.callback at run time) ---------

    def _record_contraction(self, spec: str, site: str, macs: int,
                            pdp_fj: float) -> None:
        kv = {"spec": spec, "site": site}
        self._contractions.labels(**kv).inc()
        self._macs.labels(**kv).inc(macs)
        if pdp_fj:
            self._energy.labels(**kv).inc(macs * pdp_fj)

    def _record_probe(self, spec: str, site: str, n_products: int,
                      sum_err: float, sum_abs_err: float,
                      max_ed: float) -> None:
        kv = {"spec": spec, "site": site}
        self._probe_n.labels(**kv).inc(n_products)
        self._probe_err.labels(**kv).inc(sum_err)
        self._probe_abs.labels(**kv).inc(sum_abs_err)
        self._probe_max.labels(**kv).set_max(max_ed)

    # -- substrate hooks (called from dot_general / conv at trace time) ------

    def record_contraction(self, meta, b: int, m: int, k: int, n: int,
                           site: Optional[str] = None) -> None:
        """Meter one ``(B,M,K)@(B,K,N)`` contraction under ``meta``.

        Static facts (spec, shape, MAC count, PDP price) are computed
        here, at trace time; the registry write happens at *execution*
        time through ``jax.debug.callback``, against whatever meter is
        ambient then. ``site`` names the contraction site (a
        :mod:`repro.nn.plan` name like ``"layer.3.attn.wq"``); anonymous
        contractions fall back to the shape label.
        """
        site = site or f"{b}x{m}x{k}x{n}"
        macs = int(b) * int(m) * int(k) * int(n)
        payload = (meta.spec, site, macs, pdp_per_mac_fj(meta.mult_key))
        jax.debug.callback(functools.partial(_record_cb, payload))

    def probe(self, meta, scalar_fn, a3, b3,
              site: Optional[str] = None) -> None:
        """Re-run a sampled slab per-product against the exact multiplier.

        a3/b3: the normalized integer operands ``(B, M, K)`` / ``(B, K, N)``
        (any integer dtype; wrapped into the width's operand domain, the
        same contract every approx backend applies). ``scalar_fn`` is the
        substrate's scalar product model. Error is measured per *product*
        — ``scalar_fn(a, b) − a·b`` — so the accumulated moments are
        directly comparable to the offline LUT oracle
        (``repro.core.lut.error_lut`` / ``error_moments``).
        """
        _, m, k = a3.shape
        _, _, ncols = b3.shape
        rows = min(self.probe_rows, m)
        kk = min(self.probe_k, k)
        cols = min(self.probe_cols, ncols)
        with self._lock:
            idx = (np.sort(self._rng.choice(m, size=rows, replace=False))
                   if m > rows else np.arange(rows))
        n_bits = meta.width
        a_s = mult.wrap_operand(
            jnp.asarray(a3[0], jnp.int32)[idx, :kk], n_bits)
        b_s = mult.wrap_operand(
            jnp.asarray(b3[0], jnp.int32)[:kk, :cols], n_bits)
        approx = jnp.asarray(scalar_fn(a_s[:, :, None], b_s[None, :, :]),
                             jnp.int32)
        exact = a_s[:, :, None] * b_s[None, :, :]
        err = approx - exact
        site = site or f"{a3.shape[0]}x{m}x{k}x{ncols}"
        jax.debug.callback(
            functools.partial(_probe_cb, meta.spec, site,
                              int(rows) * int(kk) * int(cols)),
            err.sum(), jnp.abs(err).sum(), jnp.abs(err).max())

    # -- derived views -------------------------------------------------------

    def summary(self) -> dict:
        """Per-spec rollup: contractions, MACs, estimated energy (fJ)."""
        out: dict = {}
        for labels, value in self._contractions.samples():
            out.setdefault(labels["spec"], {"contractions": 0, "macs": 0,
                                            "energy_pdp_fj": 0.0})
            out[labels["spec"]]["contractions"] += int(value)
        for labels, value in self._macs.samples():
            out.setdefault(labels["spec"], {"contractions": 0, "macs": 0,
                                            "energy_pdp_fj": 0.0})
            out[labels["spec"]]["macs"] += int(value)
        for labels, value in self._energy.samples():
            out[labels["spec"]]["energy_pdp_fj"] += float(value)
        return out

    def site_summary(self) -> dict:
        """Per-site rollup: contractions, MACs, energy (fJ), specs seen.

        Keys are the site labels recorded at each contraction — plan site
        names where the call site passed one (``spec.site`` /
        ``conv.edge_detect_*``), shape strings for anonymous contractions.
        A site served by several substrates (e.g. across telemetry runs)
        lists every spec and sums their energy.
        """
        out: dict = {}

        def entry(site):
            return out.setdefault(site, {"contractions": 0, "macs": 0,
                                         "energy_pdp_fj": 0.0, "specs": []})

        for labels, value in self._contractions.samples():
            e = entry(labels["site"])
            e["contractions"] += int(value)
            if labels["spec"] not in e["specs"]:
                e["specs"].append(labels["spec"])
        for labels, value in self._macs.samples():
            entry(labels["site"])["macs"] += int(value)
        for labels, value in self._energy.samples():
            entry(labels["site"])["energy_pdp_fj"] += float(value)
        for e in out.values():
            e["specs"] = sorted(e["specs"])
        return out

    def probe_moments(self, spec: Optional[str] = None) -> dict:
        """Accumulated online error moments, keyed by spec (or one spec).

        Each entry: ``{"n", "mean", "med", "max_ed"}`` — signed mean
        error, mean error distance (mean |error|), max error distance —
        comparable to ``repro.core.lut.error_moments`` /
        ``|error_lut|.mean()`` under uniform operands.
        """
        acc: dict = {}
        for labels, v in self._probe_n.samples():
            acc.setdefault(labels["spec"], dict(n=0, err=0.0, abs=0.0,
                                                max_ed=0.0))["n"] += int(v)
        for labels, v in self._probe_err.samples():
            acc[labels["spec"]]["err"] += float(v)
        for labels, v in self._probe_abs.samples():
            acc[labels["spec"]]["abs"] += float(v)
        for labels, v in self._probe_max.samples():
            a = acc[labels["spec"]]
            a["max_ed"] = max(a["max_ed"], float(v))
        out = {s: {"n": a["n"],
                   "mean": a["err"] / a["n"] if a["n"] else 0.0,
                   "med": a["abs"] / a["n"] if a["n"] else 0.0,
                   "max_ed": a["max_ed"]}
               for s, a in acc.items()}
        if spec is not None:
            return out.get(spec, {"n": 0, "mean": 0.0, "med": 0.0,
                                  "max_ed": 0.0})
        return out


# ---------------------------------------------------------------------------
# Ambient scope (process-wide, mirrors partitioning_scope's API)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[ContractionMeter] = None
_ACTIVE_LOCK = threading.Lock()


def current_meter() -> Optional[ContractionMeter]:
    """The meter installed by :func:`telemetry_scope`, or None.

    Read by ``ProductSubstrate.dot_general`` at trace time (one global
    read — the disabled path does nothing else) and by the debug
    callbacks at execution time.
    """
    return _ACTIVE


@contextlib.contextmanager
def telemetry_scope(meter: Optional[ContractionMeter]):
    """Install ``meter`` process-wide for the duration of the block.

    Mirrors ``repro.nn.substrate.partitioning_scope``, but deliberately
    process-global rather than thread-local: metered contractions execute
    on serving worker threads and JAX runtime callback threads, none of
    which inherit the installer's thread-locals. ``None`` is a no-op
    scope (disables metering inside the block); nesting restores the
    previous meter on exit.
    """
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, meter
    try:
        yield meter
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev
