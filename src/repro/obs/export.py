"""File export helpers for the observability layer.

One place that knows how to spell metrics and traces to disk, so the
launch scripts, examples, and benchmarks don't each reinvent the dump:

* :func:`write_metrics` — registry → file, format picked by suffix:
  ``.prom`` / ``.txt`` get Prometheus text exposition, everything else a
  JSON document (``registry.to_json()``).
* :func:`write_chrome_trace` — tracer → Chrome/Perfetto trace-event JSON
  (open at ``ui.perfetto.dev`` or ``chrome://tracing``).

Both create parent directories and return the resolved path.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional, Union

from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer

__all__ = ["write_metrics", "write_chrome_trace"]

_PROM_SUFFIXES = {".prom", ".txt"}


def _prepare(path: Union[str, os.PathLike]) -> Path:
    p = Path(path)
    if p.parent and str(p.parent) not in ("", "."):
        p.parent.mkdir(parents=True, exist_ok=True)
    return p


def write_metrics(registry: MetricsRegistry, path: Union[str, os.PathLike],
                  *, extra: Optional[dict] = None) -> Path:
    """Dump ``registry`` to ``path``; suffix picks the format.

    ``.prom``/``.txt`` → Prometheus text exposition (``extra`` ignored —
    that format has no place for free-form context). Anything else →
    JSON: ``{"metrics": registry.to_json(), **extra}``.
    """
    p = _prepare(path)
    if p.suffix.lower() in _PROM_SUFFIXES:
        p.write_text(registry.to_prometheus(), encoding="utf-8")
    else:
        doc = dict(extra or {})
        doc["metrics"] = registry.to_json()
        p.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n",
                     encoding="utf-8")
    return p


def write_chrome_trace(tracer: Tracer,
                       path: Union[str, os.PathLike]) -> Path:
    """Dump ``tracer`` as Chrome trace-event JSON to ``path``."""
    p = _prepare(path)
    p.write_text(tracer.chrome_trace_text(), encoding="utf-8")
    return p
