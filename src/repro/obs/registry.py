"""Metrics registry: thread-safe labeled Counter/Gauge/Histogram families.

The shared measurement substrate every layer records into: serving
telemetry (:class:`repro.serving.metrics.ServingMetrics`), the per-
contraction meters (:mod:`repro.obs.meter`), and anything else that wants
a counter. A :class:`MetricsRegistry` owns named *families*; a family plus
a label set is one time series. Two export surfaces:

* :meth:`MetricsRegistry.to_json` — a plain dict (machine-readable dumps,
  ``BENCH_serving.json`` sections, CI artifact checks);
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` headers, ``name{label="v"} value``
  samples, ``_bucket``/``_sum``/``_count`` for histograms).

Concurrency contract: every mutation takes the owning family's lock, so
the batcher worker thread and submitting threads can record concurrently;
reads (``value()``, exports) snapshot under the same lock. Families are
get-or-create — asking a registry for an existing name returns the same
family (type and label names must match), so several recorders can share
one registry without coordination.

Registries are cheap, independent objects: each
:class:`~repro.serving.metrics.ServingMetrics` defaults to a private one,
and an export surface that wants one combined dump passes a shared
registry to every recorder.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

#: default latency-style histogram buckets (seconds), Prometheus-ish.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_LabelKey = Tuple[str, ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, str]) -> _LabelKey:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} do not match declared label names "
            f"{sorted(labelnames)}")
    return tuple(str(labels[n]) for n in labelnames)


def _fmt_value(v: float) -> str:
    """Prometheus sample value: integers render bare, floats as repr."""
    if isinstance(v, bool):  # pragma: no cover - defensive
        return str(int(v))
    if float(v) == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labelnames: Sequence[str], key: _LabelKey,
                extra: Optional[Dict[str, str]] = None) -> str:
    pairs = [(n, v) for n, v in zip(labelnames, key)]
    if extra:
        pairs += sorted(extra.items())
    if not pairs:
        return ""
    def esc(s: str) -> str:
        return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")
    return "{" + ",".join(f'{n}="{esc(str(v))}"' for n, v in pairs) + "}"


class _Family:
    """One named metric family: a dict of label-tuple → series state."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        if not name or not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._series: Dict[_LabelKey, object] = {}

    # -- series access -------------------------------------------------------

    def _new_state(self):
        raise NotImplementedError

    def _get(self, key: _LabelKey):
        state = self._series.get(key)
        if state is None:
            state = self._series[key] = self._new_state()
        return state

    def labels(self, **labels) -> "_Child":
        """Bound child for one label set (create-on-first-use)."""
        return _Child(self, _label_key(self.labelnames, labels))

    @property
    def _default_key(self) -> _LabelKey:
        if self.labelnames:
            raise ValueError(
                f"metric {self.name!r} declares labels {self.labelnames}; "
                "use .labels(...)")
        return ()

    def reset(self) -> None:
        """Drop every series (zero counters, clear histograms)."""
        with self._lock:
            self._series.clear()

    # -- snapshots -----------------------------------------------------------

    def samples(self) -> list:
        """[(labels_dict, value), ...] — histograms return richer dicts."""
        with self._lock:
            return [(dict(zip(self.labelnames, key)), self._snap(state))
                    for key, state in sorted(self._series.items())]

    def _snap(self, state):
        raise NotImplementedError


class _Child:
    """A family bound to one label set; forwards mutations."""

    __slots__ = ("_family", "_key")

    def __init__(self, family: _Family, key: _LabelKey):
        self._family = family
        self._key = key

    def __getattr__(self, name):
        fam, key = self._family, self._key
        method = getattr(type(fam), "_" + name, None)
        if method is None:
            raise AttributeError(name)
        def call(*args, **kw):
            with fam._lock:
                return method(fam, fam._get(key), *args, **kw)
        return call


class Counter(_Family):
    """Monotonically increasing value (``inc`` rejects negative deltas)."""

    kind = "counter"

    def _new_state(self):
        return [0.0]

    def _inc(self, state, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counters only go up (inc({amount}))")
        state[0] += amount

    def _value(self, state) -> float:
        return state[0]

    def _snap(self, state):
        return state[0]

    def inc(self, amount: float = 1.0) -> None:
        key = self._default_key
        with self._lock:
            self._inc(self._get(key), amount)

    def value(self) -> float:
        key = self._default_key
        with self._lock:
            return self._get(key)[0]


class Gauge(_Family):
    """Value that can go anywhere (``set``/``inc``/``set_max``)."""

    kind = "gauge"

    def _new_state(self):
        return [0.0]

    def _set(self, state, v: float):
        state[0] = float(v)

    def _inc(self, state, amount: float = 1.0):
        state[0] += amount

    def _set_max(self, state, v: float):
        """Ratchet: keep the running maximum (peak gauges)."""
        state[0] = max(state[0], float(v))

    def _value(self, state) -> float:
        return state[0]

    def _snap(self, state):
        return state[0]

    def set(self, v: float) -> None:
        key = self._default_key
        with self._lock:
            self._set(self._get(key), v)

    def inc(self, amount: float = 1.0) -> None:
        key = self._default_key
        with self._lock:
            self._inc(self._get(key), amount)

    def set_max(self, v: float) -> None:
        key = self._default_key
        with self._lock:
            self._set_max(self._get(key), v)

    def value(self) -> float:
        key = self._default_key
        with self._lock:
            return self._get(key)[0]


class Histogram(_Family):
    """Cumulative-bucket histogram (+ sum and count), Prometheus layout."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")

    def _new_state(self):
        return {"counts": [0] * len(self.buckets), "sum": 0.0, "count": 0}

    def _observe(self, state, v: float):
        v = float(v)
        state["sum"] += v
        state["count"] += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                state["counts"][i] += 1

    def _snap(self, state):
        return {"buckets": dict(zip(self.buckets, state["counts"])),
                "sum": state["sum"], "count": state["count"]}

    def observe(self, v: float) -> None:
        key = self._default_key
        with self._lock:
            self._observe(self._get(key), v)


class MetricsRegistry:
    """Named metric families behind one lock-free lookup + JSON/Prom export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Sequence[str], **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help, labelnames, **kw)
                return fam
        if type(fam) is not cls or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}; cannot re-register as "
                f"{cls.kind} with labels {tuple(labelnames)}")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def families(self) -> list:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def reset(self) -> None:
        for fam in self.families():
            fam.reset()

    # -- export --------------------------------------------------------------

    def to_json(self) -> dict:
        """{name: {type, help, labelnames, samples: [{labels, value}]}}."""
        out = {}
        for fam in self.families():
            out[fam.name] = {
                "type": fam.kind,
                "help": fam.help,
                "labelnames": list(fam.labelnames),
                "samples": [{"labels": labels, "value": value}
                            for labels, value in fam.samples()],
            }
        return out

    def to_json_text(self) -> str:
        return json.dumps(self.to_json(), indent=1, sort_keys=True) + "\n"

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for labels, value in fam.samples():
                key = tuple(str(labels[n]) for n in fam.labelnames)
                if fam.kind == "histogram":
                    acc = 0
                    for b, c in value["buckets"].items():
                        acc = c  # counts are already cumulative
                        lines.append(
                            f"{fam.name}_bucket"
                            f"{_fmt_labels(fam.labelnames, key, {'le': repr(float(b))})}"
                            f" {acc}")
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_fmt_labels(fam.labelnames, key, {'le': '+Inf'})}"
                        f" {value['count']}")
                    lines.append(
                        f"{fam.name}_sum{_fmt_labels(fam.labelnames, key)} "
                        f"{_fmt_value(value['sum'])}")
                    lines.append(
                        f"{fam.name}_count{_fmt_labels(fam.labelnames, key)} "
                        f"{value['count']}")
                else:
                    lines.append(
                        f"{fam.name}{_fmt_labels(fam.labelnames, key)} "
                        f"{_fmt_value(value)}")
        return "\n".join(lines) + "\n"
