"""Tracing: nestable spans on a per-thread stack, Chrome-trace/JSONL export.

A :class:`Tracer` records *spans* — named, timed, optionally attributed
intervals — from any number of threads. Each thread keeps its own span
stack (nesting is per-thread, so a batcher worker's spans never interleave
with a submitter's), and completed spans land in one shared, lock-guarded
event list. Export surfaces:

* :meth:`Tracer.chrome_trace` — the Chrome/Perfetto trace-event JSON
  format (``{"traceEvents": [{"ph": "X", "ts": µs, "dur": µs, ...}]}``);
  load the file at ``ui.perfetto.dev`` or ``chrome://tracing``;
* :meth:`Tracer.events` — plain dicts, one per span (JSONL sinks);
* :class:`JsonlSink` — streams every completed span to a file as one JSON
  object per line (``tracer.add_sink(sink)``).

Ambient installation mirrors the meter scope
(:func:`repro.obs.meter.telemetry_scope`): :func:`tracing_scope` installs a
tracer *process-wide* — deliberately not thread-local, because serving
work happens on batcher worker threads that never see the installing
thread's locals — and :func:`trace_span` is the zero-overhead
instrumentation point: with no tracer installed it returns a shared no-op
context manager (one global read, no allocation).
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["Tracer", "JsonlSink", "tracing_scope", "current_tracer",
           "trace_span"]


class _NullSpan:
    """Reusable, reentrant no-op context manager (the disabled path)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span recorder with per-thread nesting stacks and a shared event log.

    Timestamps come from ``clock`` (default ``time.perf_counter``,
    monotonic) relative to the tracer's construction instant, exported in
    microseconds (the Chrome trace unit).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 pid: int = 1):
        self._clock = clock
        self._t0 = clock()
        self._pid = pid
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._sinks: List[Callable[[dict], None]] = []
        self._stacks = threading.local()
        self._tids: Dict[int, int] = {}          # thread ident -> small tid
        self._tid_counter = itertools.count(1)

    # -- internals -----------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = next(self._tid_counter)
        return tid

    def _stack(self) -> list:
        st = getattr(self._stacks, "stack", None)
        if st is None:
            st = self._stacks.stack = []
        return st

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)
            sinks = list(self._sinks)
        for s in sinks:
            s(ev)

    # -- recording -----------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", **attrs):
        """Record a span around the block; nests on this thread's stack."""
        stack = self._stack()
        depth = len(stack)
        parent = stack[-1] if stack else None
        stack.append(name)
        ts = self._now_us()
        try:
            yield self
        finally:
            dur = self._now_us() - ts
            stack.pop()
            args: Dict[str, Any] = {"depth": depth}
            if parent is not None:
                args["parent"] = parent
            args.update(attrs)
            self._emit({"name": name, "cat": cat or "span", "ph": "X",
                        "ts": ts, "dur": dur, "pid": self._pid,
                        "tid": self._tid(), "args": args})

    def event(self, name: str, start_s: float, dur_s: float,
              cat: str = "", **attrs) -> None:
        """Record a retroactive span from absolute ``clock`` readings.

        ``start_s`` is a raw ``clock()`` value (e.g. a ticket's
        ``enqueued_at``) — used for intervals measured outside a ``with``
        block, like queue-wait time.
        """
        self._emit({"name": name, "cat": cat or "span", "ph": "X",
                    "ts": (start_s - self._t0) * 1e6, "dur": dur_s * 1e6,
                    "pid": self._pid, "tid": self._tid(),
                    "args": dict(attrs)})

    def instant(self, name: str, cat: str = "", **attrs) -> None:
        """Zero-duration marker event."""
        self._emit({"name": name, "cat": cat or "instant", "ph": "i",
                    "ts": self._now_us(), "s": "t", "pid": self._pid,
                    "tid": self._tid(), "args": dict(attrs)})

    def add_sink(self, sink: Callable[[dict], None]) -> None:
        """Stream every completed event to ``sink(event_dict)`` as well."""
        with self._lock:
            self._sinks.append(sink)

    # -- export --------------------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> dict:
        """Chrome/Perfetto trace-event JSON object (``traceEvents`` list)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def chrome_trace_text(self) -> str:
        return json.dumps(self.chrome_trace(), indent=1) + "\n"


class JsonlSink:
    """Span sink writing one JSON object per line; close() flushes.

    Usable as a context manager::

        with JsonlSink(path) as sink:
            tracer.add_sink(sink)
            ...
    """

    def __init__(self, path):
        self._lock = threading.Lock()
        self._fh = open(path, "w", encoding="utf-8")

    def __call__(self, ev: dict) -> None:
        line = json.dumps(ev) + "\n"
        with self._lock:
            if not self._fh.closed:
                self._fh.write(line)

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Ambient tracer (process-wide, like the meter scope)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[Tracer] = None
_ACTIVE_LOCK = threading.Lock()


def current_tracer() -> Optional[Tracer]:
    """The tracer installed by :func:`tracing_scope`, or None.

    Process-global on purpose: serving spans are recorded on batcher
    worker threads that inherit nothing thread-local from the installer.
    """
    return _ACTIVE


@contextlib.contextmanager
def tracing_scope(tracer: Optional[Tracer]):
    """Install ``tracer`` process-wide for the duration of the block.

    Nesting restores the previous tracer on exit; ``None`` is a no-op
    scope (uninstalls tracing inside the block). Concurrent scopes from
    different threads race on the single global slot — install from one
    place, as the launch/benchmark layers do.
    """
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, tracer
    try:
        yield tracer
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev


def trace_span(name: str, cat: str = "", **attrs):
    """Span on the ambient tracer; shared no-op when tracing is off."""
    t = _ACTIVE
    if t is None:
        return _NULL_SPAN
    return t.span(name, cat, **attrs)
