"""Observability layer: metrics registry, tracing, substrate meters.

The measurement substrate the rest of the repo records into. See
``docs/observability.md`` for the tour; the short map:

* :mod:`repro.obs.registry` — thread-safe labeled Counter/Gauge/Histogram
  families with JSON + Prometheus-text export;
* :mod:`repro.obs.trace` — nestable spans, Chrome/Perfetto trace export,
  ambient :func:`tracing_scope` / :func:`trace_span`;
* :mod:`repro.obs.meter` — per-contraction MAC/energy/error meters hooked
  into ``ProductSubstrate.dot_general`` via :func:`telemetry_scope`;
* :mod:`repro.obs.export` — file dump helpers for both.

Everything is zero-overhead-by-default: with no ambient scope installed,
instrumented code paths do one global read and nothing else.
"""
from repro.obs.export import write_chrome_trace, write_metrics
from repro.obs.meter import (ContractionMeter, current_meter, pdp_per_mac_fj,
                             telemetry_scope)
from repro.obs.registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.trace import (JsonlSink, Tracer, current_tracer, trace_span,
                             tracing_scope)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "DEFAULT_BUCKETS",
    "Tracer", "JsonlSink", "tracing_scope", "current_tracer", "trace_span",
    "ContractionMeter", "telemetry_scope", "current_meter", "pdp_per_mac_fj",
    "write_metrics", "write_chrome_trace",
]
