"""Serving telemetry: counters, latency percentiles, occupancy histogram.

One :class:`ServingMetrics` instance is shared by a batcher and the service
draining it, so every layer (enqueue, flush, compile, completion) records
into the same snapshot. All methods are thread-safe — the batcher worker and
submitting threads hit them concurrently.

Latencies are kept in a bounded reservoir (uniform replacement past the cap)
so a long-running service reports stable percentiles at O(1) memory.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, Optional

_RESERVOIR_CAP = 8192


class ServingMetrics:
    """Counters + latency/occupancy telemetry for a serving pipeline.

    Flush reasons (``batches_by_reason``):

    * ``"size"``    — bucket reached ``max_batch_size``;
    * ``"timeout"`` — oldest request exceeded ``max_wait_s``;
    * ``"drain"``   — explicit flush/stop drained a partial bucket.
    """

    def __init__(self, clock=time.perf_counter):
        self._lock = threading.Lock()
        self._clock = clock
        self._rng = random.Random(0)
        self.reset()

    def reset(self) -> None:
        """Zero every counter and restart the throughput clock (benchmarks
        call this after warmup so compiles don't pollute the measurement)."""
        with self._lock:
            self.started_at = self._clock()
            self.requests_enqueued = 0
            self.requests_served = 0
            self.requests_failed = 0
            self.batches_flushed = 0
            self.batches_by_reason: Dict[str, int] = {}
            self.compiled_calls = 0
            self.queue_depth = 0
            self.queue_depth_peak = 0
            self.occupancy_hist: Dict[int, int] = {}   # batch size -> count
            self._occupancy_denom = 0                  # Σ max_batch / batches
            self._occupancy_num = 0                    # Σ actual batch sizes
            self._latencies: list[float] = []          # seconds, reservoir
            self._latency_count = 0

    # -- recording -----------------------------------------------------------

    def record_enqueue(self, depth: int) -> None:
        with self._lock:
            self.requests_enqueued += 1
            self.queue_depth = depth
            self.queue_depth_peak = max(self.queue_depth_peak, depth)

    def record_batch(self, size: int, reason: str,
                     max_batch_size: int) -> None:
        with self._lock:
            self.batches_flushed += 1
            self.batches_by_reason[reason] = \
                self.batches_by_reason.get(reason, 0) + 1
            self.occupancy_hist[size] = self.occupancy_hist.get(size, 0) + 1
            self._occupancy_num += size
            self._occupancy_denom += max_batch_size

    def record_done(self, latency_s: float, ok: bool = True,
                    depth: Optional[int] = None) -> None:
        with self._lock:
            if ok:
                self.requests_served += 1
            else:
                self.requests_failed += 1
            if depth is not None:
                self.queue_depth = depth
            self._latency_count += 1
            if len(self._latencies) < _RESERVOIR_CAP:
                self._latencies.append(latency_s)
            else:  # uniform reservoir replacement
                j = self._rng.randrange(self._latency_count)
                if j < _RESERVOIR_CAP:
                    self._latencies[j] = latency_s

    def record_compile(self) -> None:
        with self._lock:
            self.compiled_calls += 1

    # -- derived views -------------------------------------------------------

    def latency_percentile(self, p: float) -> float:
        """p in [0, 100] → latency seconds (0.0 when nothing recorded)."""
        with self._lock:
            lat = sorted(self._latencies)
        if not lat:
            return 0.0
        idx = min(len(lat) - 1, max(0, round(p / 100.0 * (len(lat) - 1))))
        return lat[idx]

    def throughput(self) -> float:
        """Requests served per second of wall clock since construction."""
        dt = self._clock() - self.started_at
        return self.requests_served / dt if dt > 0 else 0.0

    def mean_occupancy(self) -> float:
        """Mean batch fill fraction: Σ size / Σ max_batch over flushes."""
        with self._lock:
            if not self._occupancy_denom:
                return 0.0
            return self._occupancy_num / self._occupancy_denom

    def snapshot(self) -> dict:
        """Point-in-time dict of every counter + derived stats (for logs)."""
        with self._lock:
            hist = dict(sorted(self.occupancy_hist.items()))
            reasons = dict(sorted(self.batches_by_reason.items()))
            base = {
                "requests_enqueued": self.requests_enqueued,
                "requests_served": self.requests_served,
                "requests_failed": self.requests_failed,
                "batches_flushed": self.batches_flushed,
                "batches_by_reason": reasons,
                "compiled_calls": self.compiled_calls,
                "queue_depth": self.queue_depth,
                "queue_depth_peak": self.queue_depth_peak,
                "occupancy_hist": hist,
            }
        base["mean_occupancy"] = self.mean_occupancy()
        base["throughput_rps"] = self.throughput()
        for p in (50, 95, 99):
            base[f"latency_p{p}_ms"] = self.latency_percentile(p) * 1e3
        return base

    def format_table(self) -> str:
        """Human-readable multi-line summary (examples / benchmarks)."""
        s = self.snapshot()
        occ = " ".join(f"{k}:{v}" for k, v in s["occupancy_hist"].items()) \
            or "-"
        reasons = " ".join(f"{k}:{v}" for k, v in s["batches_by_reason"].items()) \
            or "-"
        return "\n".join([
            f"requests   in={s['requests_enqueued']} "
            f"served={s['requests_served']} failed={s['requests_failed']}",
            f"batches    n={s['batches_flushed']} ({reasons}) "
            f"occupancy={s['mean_occupancy']:.2f} [{occ}]",
            f"queue      depth={s['queue_depth']} peak={s['queue_depth_peak']}",
            f"latency    p50={s['latency_p50_ms']:.2f}ms "
            f"p95={s['latency_p95_ms']:.2f}ms p99={s['latency_p99_ms']:.2f}ms",
            f"throughput {s['throughput_rps']:.1f} req/s "
            f"(compiled_calls={s['compiled_calls']})",
        ])
