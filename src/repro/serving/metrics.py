"""Serving telemetry: counters, latency percentiles, occupancy histogram.

One :class:`ServingMetrics` instance is shared by a batcher and the service
draining it, so every layer (enqueue, flush, compile, completion) records
into the same snapshot. All methods are thread-safe — the batcher worker and
submitting threads hit them concurrently.

Since the observability PR the counters live in a
:class:`repro.obs.registry.MetricsRegistry` (``serving_*`` families), so a
serving process exports one combined Prometheus/JSON dump with the
substrate meters by passing a shared registry. The public surface is
unchanged: the historical attributes (``requests_served``,
``batches_by_reason``, ``occupancy_hist``, ...) are read-only properties
over the registry, and ``snapshot()``/``format_table()`` render the same
shapes as before. Latencies additionally feed a bounded reservoir (uniform
replacement past the cap) so a long-running service reports stable
percentiles at O(1) memory — the registry histogram holds the cumulative
bucket view for export, the reservoir answers ``latency_percentile``.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, Optional

from repro.obs.registry import MetricsRegistry

_RESERVOIR_CAP = 8192

#: latency bucket bounds (seconds) for the exported histogram.
_LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class ServingMetrics:
    """Counters + latency/occupancy telemetry for a serving pipeline.

    ``registry``: optional shared :class:`MetricsRegistry`; by default each
    instance owns a private one. Two instances recording into the *same*
    registry share series (their counts merge) — share a registry for one
    combined export, not for isolation.

    Flush reasons (``batches_by_reason``):

    * ``"size"``    — bucket reached ``max_batch_size``;
    * ``"timeout"`` — oldest request exceeded ``max_wait_s``;
    * ``"drain"``   — explicit flush/stop drained a partial bucket.
    """

    def __init__(self, clock=time.perf_counter,
                 registry: Optional[MetricsRegistry] = None):
        self._lock = threading.Lock()
        self._clock = clock
        self._rng = random.Random(0)
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._enqueued = r.counter("serving_requests_enqueued_total",
                                   "requests submitted to the batcher")
        self._served = r.counter("serving_requests_served_total",
                                 "requests completed successfully")
        self._failed = r.counter("serving_requests_failed_total",
                                 "requests completed with an error")
        self._batches = r.counter("serving_batches_flushed_total",
                                  "batches flushed, by flush reason",
                                  ("reason",))
        self._compiles = r.counter("serving_compiled_calls_total",
                                   "XLA compilations triggered (new shapes)")
        self._depth = r.gauge("serving_queue_depth",
                              "requests waiting in the batcher queue")
        self._depth_peak = r.gauge("serving_queue_depth_peak",
                                   "high-water mark of the batcher queue")
        self._batch_sizes = r.counter("serving_batch_size_total",
                                      "batches flushed, by actual size",
                                      ("size",))
        self._slots_used = r.counter("serving_batch_slots_used_total",
                                     "sum of actual batch sizes")
        self._slots_total = r.counter("serving_batch_slots_total",
                                      "sum of max_batch_size over flushes")
        self._latency = r.histogram("serving_request_latency_seconds",
                                    "request latency (enqueue to done)",
                                    buckets=_LATENCY_BUCKETS)
        self._worker_batches = r.counter("serving_worker_batches_total",
                                         "batches served, by worker",
                                         ("worker",))
        self._worker_busy = r.counter("serving_worker_busy_seconds_total",
                                      "seconds spent serving batches, by "
                                      "worker (occupancy = busy / wall)",
                                      ("worker",))
        self._worker_errors = r.counter("serving_worker_errors_total",
                                        "per-payload failures isolated on a "
                                        "worker, by worker",
                                        ("worker",))
        self._inflight = r.gauge("serving_inflight_batches",
                                 "batches dispatched but not yet finalized "
                                 "(device-utilization proxy)")
        self._inflight_peak = r.gauge("serving_inflight_batches_peak",
                                      "high-water mark of concurrently "
                                      "in-flight batches")
        self.reset()

    def reset(self) -> None:
        """Zero every counter and restart the throughput clock (benchmarks
        call this after warmup so compiles don't pollute the measurement).

        Resets only this instance's ``serving_*`` families — other
        recorders in a shared registry are untouched."""
        with self._lock:
            self.started_at = self._clock()
            self._latencies: list[float] = []          # seconds, reservoir
            self._latency_count = 0
        for fam in (self._enqueued, self._served, self._failed, self._batches,
                    self._compiles, self._depth, self._depth_peak,
                    self._batch_sizes, self._slots_used, self._slots_total,
                    self._latency, self._worker_batches, self._worker_busy,
                    self._worker_errors, self._inflight, self._inflight_peak):
            fam.reset()

    # -- recording -----------------------------------------------------------

    def record_enqueue(self, depth: int) -> None:
        self._enqueued.inc()
        self._depth.set(depth)
        self._depth_peak.set_max(depth)

    def record_batch(self, size: int, reason: str,
                     max_batch_size: int) -> None:
        self._batches.labels(reason=reason).inc()
        self._batch_sizes.labels(size=size).inc()
        self._slots_used.inc(size)
        self._slots_total.inc(max_batch_size)

    def record_done(self, latency_s: float, ok: bool = True,
                    depth: Optional[int] = None) -> None:
        (self._served if ok else self._failed).inc()
        if depth is not None:
            self._depth.set(depth)
        self._latency.observe(latency_s)
        with self._lock:
            self._latency_count += 1
            if len(self._latencies) < _RESERVOIR_CAP:
                self._latencies.append(latency_s)
            else:  # uniform reservoir replacement
                j = self._rng.randrange(self._latency_count)
                if j < _RESERVOIR_CAP:
                    self._latencies[j] = latency_s

    def record_compile(self) -> None:
        self._compiles.inc()

    def record_worker_batch(self, worker: str, busy_s: float) -> None:
        """One batch served end-to-end by ``worker`` in ``busy_s`` seconds."""
        self._worker_batches.labels(worker=str(worker)).inc()
        self._worker_busy.labels(worker=str(worker)).inc(max(0.0, busy_s))

    def record_worker_error(self, worker: str) -> None:
        """One payload failed (and was isolated) on ``worker``."""
        self._worker_errors.labels(worker=str(worker)).inc()

    def record_inflight(self, delta: int) -> None:
        """Batch entered (+1) / left (-1) the dispatched-not-finalized window."""
        self._inflight.inc(delta)
        if delta > 0:
            self._inflight_peak.set_max(self._inflight.value())

    # -- historical attribute surface (read-only, registry-backed) -----------

    @property
    def requests_enqueued(self) -> int:
        return int(self._enqueued.value())

    @property
    def requests_served(self) -> int:
        return int(self._served.value())

    @property
    def requests_failed(self) -> int:
        return int(self._failed.value())

    @property
    def batches_flushed(self) -> int:
        return sum(int(v) for _, v in self._batches.samples())

    @property
    def batches_by_reason(self) -> Dict[str, int]:
        return {labels["reason"]: int(v)
                for labels, v in self._batches.samples()}

    @property
    def compiled_calls(self) -> int:
        return int(self._compiles.value())

    @property
    def queue_depth(self) -> int:
        return int(self._depth.value())

    @property
    def queue_depth_peak(self) -> int:
        return int(self._depth_peak.value())

    @property
    def occupancy_hist(self) -> Dict[int, int]:
        return {int(labels["size"]): int(v)
                for labels, v in self._batch_sizes.samples()}

    @property
    def worker_batches(self) -> Dict[str, int]:
        """{worker: batches served} over every worker that served one."""
        return {labels["worker"]: int(v)
                for labels, v in self._worker_batches.samples()}

    @property
    def worker_busy_seconds(self) -> Dict[str, float]:
        return {labels["worker"]: float(v)
                for labels, v in self._worker_busy.samples()}

    @property
    def worker_errors(self) -> int:
        """Total payload failures isolated across all workers."""
        return sum(int(v) for _, v in self._worker_errors.samples())

    @property
    def inflight_batches(self) -> int:
        return int(self._inflight.value())

    @property
    def inflight_peak(self) -> int:
        """Max batches simultaneously dispatched-not-finalized (>1 proves
        batch k+1 was dispatched while batch k still ran)."""
        return int(self._inflight_peak.value())

    # -- derived views -------------------------------------------------------

    def latency_percentile(self, p: float) -> float:
        """p in [0, 100] → latency seconds (0.0 when nothing recorded)."""
        with self._lock:
            lat = sorted(self._latencies)
        if not lat:
            return 0.0
        idx = min(len(lat) - 1, max(0, round(p / 100.0 * (len(lat) - 1))))
        return lat[idx]

    def throughput(self) -> float:
        """Requests served per second of wall clock since construction."""
        with self._lock:  # started_at races with reset() otherwise
            dt = self._clock() - self.started_at
        served = self.requests_served
        return served / dt if dt > 0 else 0.0

    def mean_occupancy(self) -> float:
        """Mean batch fill fraction: Σ size / Σ max_batch over flushes."""
        denom = self._slots_total.value()
        if not denom:
            return 0.0
        return self._slots_used.value() / denom

    def snapshot(self) -> dict:
        """Point-in-time dict of every counter + derived stats (for logs)."""
        base = {
            "requests_enqueued": self.requests_enqueued,
            "requests_served": self.requests_served,
            "requests_failed": self.requests_failed,
            "batches_flushed": self.batches_flushed,
            "batches_by_reason": dict(sorted(
                self.batches_by_reason.items())),
            "compiled_calls": self.compiled_calls,
            "queue_depth": self.queue_depth,
            "queue_depth_peak": self.queue_depth_peak,
            "occupancy_hist": dict(sorted(self.occupancy_hist.items())),
            "worker_batches": dict(sorted(self.worker_batches.items())),
            "worker_busy_seconds": {
                k: round(v, 6)
                for k, v in sorted(self.worker_busy_seconds.items())},
            "worker_errors": self.worker_errors,
            "inflight_peak": self.inflight_peak,
        }
        base["mean_occupancy"] = self.mean_occupancy()
        base["throughput_rps"] = self.throughput()
        for p in (50, 95, 99):
            base[f"latency_p{p}_ms"] = self.latency_percentile(p) * 1e3
        return base

    def format_table(self) -> str:
        """Human-readable multi-line summary (examples / benchmarks)."""
        s = self.snapshot()
        occ = " ".join(f"{k}:{v}" for k, v in s["occupancy_hist"].items()) \
            or "-"
        reasons = " ".join(f"{k}:{v}" for k, v in s["batches_by_reason"].items()) \
            or "-"
        workers = " ".join(f"{k}:{v}"
                           for k, v in s["worker_batches"].items()) or "-"
        return "\n".join([
            f"requests   in={s['requests_enqueued']} "
            f"served={s['requests_served']} failed={s['requests_failed']}",
            f"batches    n={s['batches_flushed']} ({reasons}) "
            f"occupancy={s['mean_occupancy']:.2f} [{occ}]",
            f"queue      depth={s['queue_depth']} peak={s['queue_depth_peak']}",
            f"workers    [{workers}] errors={s['worker_errors']} "
            f"inflight_peak={s['inflight_peak']}",
            f"latency    p50={s['latency_p50_ms']:.2f}ms "
            f"p95={s['latency_p95_ms']:.2f}ms p99={s['latency_p99_ms']:.2f}ms",
            f"throughput {s['throughput_rps']:.1f} req/s "
            f"(compiled_calls={s['compiled_calls']})",
        ])
