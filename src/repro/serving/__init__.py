"""Serving subsystem: shared scheduling core + LM engine + vision service.

* :mod:`repro.serving.batcher` — SlotScheduler (continuous batching) and
  MicroBatcher (dynamic micro-batching) primitives;
* :mod:`repro.serving.metrics` — ServingMetrics telemetry;
* :mod:`repro.serving.engine` — batched LM ServingEngine;
* :mod:`repro.serving.edge_service` — EdgeDetectService over the
  ProductSubstrate registry.
"""
from repro.serving.batcher import MicroBatcher, SlotScheduler, Ticket  # noqa: F401
from repro.serving.edge_service import EdgeDetectService  # noqa: F401
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.metrics import ServingMetrics  # noqa: F401
