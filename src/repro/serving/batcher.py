"""Scheduling core shared by LM and vision serving.

Two primitives:

* :class:`SlotScheduler` — fixed-slot continuous batching (a FIFO queue
  feeding ``n_slots`` concurrent slots, refilled as requests finish). The LM
  :class:`~repro.serving.engine.ServingEngine` decode loop runs on this.
* :class:`MicroBatcher` — dynamic micro-batching for one-shot requests: a
  thread-safe queue bucketed by an arbitrary key (shape buckets for vision),
  flushed when a bucket reaches ``max_batch_size`` or its oldest request has
  waited ``max_wait_s``, drained by ``n_workers`` background worker threads.
  The vision :class:`~repro.serving.edge_service.EdgeDetectService` runs on
  this.

Multi-worker pipeline: every worker loop pops flushable buckets from the
shared queue under one condition variable, so with ``n_workers > 1`` batch
``k+1`` is dispatched while batch ``k`` still runs. Work is split into two
phases to make that overlap real for accelerator backends:

* ``process_fn(bucket_key, payloads) -> raw`` — the *dispatch* phase. It may
  return asynchronously-dispatched device values (e.g. the result of a
  jitted call **without** ``block_until_ready``), so the worker releases the
  device as soon as the computation is enqueued.
* ``finalize_fn(bucket_key, raw) -> results`` — optional *delivery* phase:
  blocks until the dispatched values are ready and materializes one result
  per payload, in order. Without a ``finalize_fn``, ``process_fn`` must
  return the final results itself.

Fault isolation: a failing batch is retried payload-by-payload, so a poison
payload fails only its own ticket (the error re-raises from
``Ticket.result()``), healthy tickets from the same batch still get served,
the worker loop stays alive, and each poisoned payload increments the
``serving_worker_errors_total`` counter. ``process_fn`` must therefore be
safe to re-invoke per payload (pure compute — true for every substrate
contraction).

Both primitives report into the same
:class:`~repro.serving.metrics.ServingMetrics` schema, so LM and vision
serving share one scheduling + telemetry core.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Hashable, Iterable, List, Optional, Tuple

from repro.obs.trace import current_tracer, trace_span
from repro.serving.metrics import ServingMetrics


# ---------------------------------------------------------------------------
# Fixed-slot continuous batching (LM decode)
# ---------------------------------------------------------------------------


class SlotScheduler:
    """FIFO queue feeding a fixed pool of batch slots.

    The pattern under continuous batching: a decode step advances every
    occupied slot by one token; finished requests release their slot, which
    is refilled from the queue on the next step. This class owns only the
    queue/slot bookkeeping — the engine owns per-slot model state.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.slots: List[Optional[Any]] = [None] * n_slots
        self.queue: collections.deque = collections.deque()

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def submit(self, item: Any) -> None:
        self.queue.append(item)

    def extend(self, items: Iterable[Any]) -> None:
        self.queue.extend(items)

    def refill(self) -> List[Tuple[int, Any]]:
        """Fill empty slots from the queue; returns (slot_idx, item) pairs
        for the newly seated items."""
        seated = []
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                item = self.queue.popleft()
                self.slots[i] = item
                seated.append((i, item))
        return seated

    def release(self, idx: int) -> None:
        self.slots[idx] = None

    def occupied(self) -> List[Tuple[int, Any]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        """True while any slot is occupied or requests are still queued."""
        return bool(self.queue) or any(s is not None for s in self.slots)


# ---------------------------------------------------------------------------
# Dynamic micro-batching (one-shot requests)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted request; ``result()`` blocks until served."""

    payload: Any
    bucket: Hashable
    enqueued_at: float
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    _value: Any = None
    _error: Optional[BaseException] = None
    latency_s: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._value


class MicroBatcher:
    """Dynamic micro-batcher: bucketed queue + size/timeout flush policy.

    process_fn(bucket_key, payloads) -> raw
        Called on a worker thread with 1..max_batch_size payloads that share
        a bucket key. With no ``finalize_fn`` it must return one result per
        payload, in order; with one, it may return an opaque in-flight value
        (non-blocking device dispatch) that ``finalize_fn`` materializes.
    finalize_fn(bucket_key, raw) -> results
        Optional delivery phase: blocks on the dispatched value and returns
        one result per payload, in order. Runs on the same worker, but with
        ``n_workers > 1`` another worker dispatches the next batch
        concurrently — host/device overlap.
    bucket_fn(payload) -> hashable
        Bucket assignment (e.g. padded image shape); ``None`` puts everything
        in one bucket. Buckets never mix inside a batch.
    max_wait_s
        A non-full bucket flushes once its *oldest* request has waited this
        long; ``0`` flushes on every worker wakeup (latency-optimal).
    n_workers
        Worker threads draining the queue. Each popped batch is owned end to
        end by one worker; pops are serialized under the queue lock, so
        tickets are never lost, duplicated, or cross-wired regardless of
        worker count.
    """

    def __init__(self, process_fn: Callable[[Hashable, List[Any]], Any],
                 *, max_batch_size: int = 8, max_wait_s: float = 2e-3,
                 bucket_fn: Optional[Callable[[Any], Hashable]] = None,
                 finalize_fn: Optional[Callable[[Hashable, Any], List[Any]]] = None,
                 n_workers: int = 1,
                 metrics: Optional[ServingMetrics] = None,
                 clock=time.perf_counter):
        if max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {max_batch_size}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.process_fn = process_fn
        self.finalize_fn = finalize_fn
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self.n_workers = n_workers
        self.bucket_fn = bucket_fn or (lambda _payload: None)
        self.metrics = metrics or ServingMetrics()
        self._clock = clock
        self._cv = threading.Condition()
        self._buckets: Dict[Hashable, collections.deque] = {}
        self._running = False
        self._stopped = False
        self._threads: List[threading.Thread] = []

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "MicroBatcher":
        with self._cv:
            self._stopped = False
            if self._running:
                return self
            self._running = True
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True,
                             name=f"micro-batcher-{i}")
            for i in range(self.n_workers)]
        for t in self._threads:
            t.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop every worker; by default serve everything still queued first.
        Further submissions raise until the batcher is start()ed again."""
        with self._cv:
            self._stopped = True
            was_running = self._running
            self._running = False
            self._cv.notify_all()
        if was_running:
            for t in self._threads:
                t.join()
            self._threads = []
        if drain:
            self._drain_inline()

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ----------------------------------------------------------

    def submit(self, payload: Any) -> Ticket:
        t = Ticket(payload=payload, bucket=self.bucket_fn(payload),
                   enqueued_at=self._clock())
        with self._cv:
            if self._stopped:
                # a post-stop ticket would sit in the queue forever (no
                # worker, no pending drain) — fail fast instead
                raise RuntimeError("MicroBatcher is stopped; call start()")
            self._buckets.setdefault(t.bucket, collections.deque()).append(t)
            depth = sum(len(q) for q in self._buckets.values())
            self._cv.notify_all()
        self.metrics.record_enqueue(depth)
        return t

    def submit_many(self, payloads: Iterable[Any]) -> List[Ticket]:
        return [self.submit(p) for p in payloads]

    @property
    def depth(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._buckets.values())

    @property
    def running(self) -> bool:
        with self._cv:
            return self._running

    # -- flush policy --------------------------------------------------------

    def _pop_ready_locked(self, now: float, drain: bool):
        """(bucket, tickets, reason) for the most urgent flushable bucket, or
        None. A bucket is flushable when full, expired, or draining; among
        flushable buckets the oldest head wins regardless of trigger, so a
        continuously-full hot bucket cannot starve an expired one past its
        max_wait_s."""
        best = None
        for key, q in self._buckets.items():
            if not q:
                continue
            head = q[0].enqueued_at
            if len(q) >= self.max_batch_size:
                reason = "size"
            elif now - head >= self.max_wait_s:
                reason = "timeout"
            elif drain:
                reason = "drain"
            else:
                continue
            if best is None or head < best[2]:
                best = (key, reason, head)
        if best is None:
            return None
        key, reason, _ = best
        q = self._buckets[key]
        batch = [q.popleft() for _ in range(min(self.max_batch_size, len(q)))]
        if not q:
            del self._buckets[key]
        return key, batch, reason

    def _next_deadline_locked(self) -> Optional[float]:
        heads = [q[0].enqueued_at for q in self._buckets.values() if q]
        return min(heads) + self.max_wait_s if heads else None

    # -- execution -----------------------------------------------------------

    def _invoke(self, key: Hashable, payloads: List[Any], reason: str,
                worker: str) -> List[Any]:
        """One dispatch(+finalize) round for ``payloads``; raises on error.

        The in-flight gauge covers dispatch-to-finalize, so its peak shows
        how many batches genuinely overlapped on the device.
        """
        n = len(payloads)
        self.metrics.record_inflight(+1)
        try:
            with trace_span("batch.process", "serving", bucket=str(key),
                            size=n, reason=reason, worker=worker):
                raw = self.process_fn(key, payloads)
            if self.finalize_fn is not None:
                with trace_span("batch.finalize", "serving", bucket=str(key),
                                size=n, worker=worker):
                    results = self.finalize_fn(key, raw)
            else:
                results = raw
        finally:
            self.metrics.record_inflight(-1)
        if len(results) != n:
            raise RuntimeError(
                f"process_fn returned {len(results)} results for "
                f"{n} payloads (bucket {key!r})")
        return list(results)

    def _run_batch(self, key: Hashable, batch: List[Ticket], reason: str,
                   worker: str):
        """(results, errors) for the batch, isolating poison payloads.

        On a batch failure the payloads are retried one by one, so only the
        ticket(s) whose payload actually raises carry an error — the rest of
        the batch is still served and the worker loop survives.
        """
        try:
            results = self._invoke(key, [t.payload for t in batch], reason,
                                   worker)
            return results, [None] * len(batch)
        except BaseException as batch_err:  # noqa: BLE001 - isolate below
            if len(batch) == 1:
                self.metrics.record_worker_error(worker)
                return [None], [batch_err]
            results, errs = [], []
            for t in batch:
                try:
                    results.append(
                        self._invoke(key, [t.payload], "isolate", worker)[0])
                    errs.append(None)
                except BaseException as e:  # noqa: BLE001 - per-ticket error
                    self.metrics.record_worker_error(worker)
                    results.append(None)
                    errs.append(e)
            return results, errs

    def _serve(self, key: Hashable, batch: List[Ticket], reason: str,
               worker: str = "drain") -> None:
        t_busy = self._clock()
        try:
            self.metrics.record_batch(len(batch), reason, self.max_batch_size)
            tracer = current_tracer()
            if tracer is not None:
                # retroactive span: the head ticket's time in queue. Only
                # meaningful when the batcher runs on the tracer's clock
                # (both default to time.perf_counter).
                head = min(t.enqueued_at for t in batch)
                tracer.event("batch.queue_wait", head, self._clock() - head,
                             "serving", bucket=str(key), size=len(batch),
                             reason=reason, worker=worker)
            results, errs = self._run_batch(key, batch, reason, worker)
        except BaseException as e:  # noqa: BLE001 - telemetry failure: still
            # deliver something so no ticket blocks forever
            results = [None] * len(batch)
            errs = [e] * len(batch)
        now = self._clock()
        depth = self.depth
        for t, r, e in zip(batch, results, errs):
            t._value, t._error = r, e
            t.latency_s = now - t.enqueued_at
            self.metrics.record_done(t.latency_s, ok=e is None, depth=depth)
            t._event.set()
        self.metrics.record_worker_batch(worker, self._clock() - t_busy)

    def _worker(self, idx: int) -> None:
        worker = str(idx)
        while True:
            with self._cv:
                while True:
                    if not self._running:
                        return
                    now = self._clock()
                    ready = self._pop_ready_locked(now, drain=False)
                    if ready is not None:
                        break
                    deadline = self._next_deadline_locked()
                    timeout = None if deadline is None \
                        else max(0.0, deadline - now)
                    self._cv.wait(timeout)
            try:
                self._serve(*ready, worker=worker)
            except BaseException as e:  # noqa: BLE001 - keep the loop alive
                # _serve already shields itself; this is the last-resort
                # guard so a worker can never die holding unresolved tickets
                for t in ready[1]:
                    if not t.done():
                        t._error = e
                        t._event.set()

    def _drain_inline(self) -> None:
        """Serve every queued ticket on the calling thread (stop/flush)."""
        while True:
            with self._cv:
                ready = self._pop_ready_locked(self._clock(), drain=True)
            if ready is None:
                return
            self._serve(*ready)

    def flush(self) -> None:
        """Synchronously serve everything currently queued (testing/shutdown
        aid; safe while workers run — pops are mutually exclusive)."""
        self._drain_inline()
