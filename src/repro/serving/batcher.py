"""Scheduling core shared by LM and vision serving.

Two primitives:

* :class:`SlotScheduler` — fixed-slot continuous batching (a FIFO queue
  feeding ``n_slots`` concurrent slots, refilled as requests finish). The LM
  :class:`~repro.serving.engine.ServingEngine` decode loop runs on this.
* :class:`MicroBatcher` — dynamic micro-batching for one-shot requests: a
  thread-safe queue bucketed by an arbitrary key (shape buckets for vision),
  flushed when a bucket reaches ``max_batch_size`` or its oldest request has
  waited ``max_wait_s``, drained by a background worker thread. The vision
  :class:`~repro.serving.edge_service.EdgeDetectService` runs on this.

Both report into the same :class:`~repro.serving.metrics.ServingMetrics`
schema, so LM and vision serving share one scheduling + telemetry core.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Hashable, Iterable, List, Optional, Tuple

from repro.obs.trace import current_tracer, trace_span
from repro.serving.metrics import ServingMetrics


# ---------------------------------------------------------------------------
# Fixed-slot continuous batching (LM decode)
# ---------------------------------------------------------------------------


class SlotScheduler:
    """FIFO queue feeding a fixed pool of batch slots.

    The pattern under continuous batching: a decode step advances every
    occupied slot by one token; finished requests release their slot, which
    is refilled from the queue on the next step. This class owns only the
    queue/slot bookkeeping — the engine owns per-slot model state.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.slots: List[Optional[Any]] = [None] * n_slots
        self.queue: collections.deque = collections.deque()

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def submit(self, item: Any) -> None:
        self.queue.append(item)

    def extend(self, items: Iterable[Any]) -> None:
        self.queue.extend(items)

    def refill(self) -> List[Tuple[int, Any]]:
        """Fill empty slots from the queue; returns (slot_idx, item) pairs
        for the newly seated items."""
        seated = []
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                item = self.queue.popleft()
                self.slots[i] = item
                seated.append((i, item))
        return seated

    def release(self, idx: int) -> None:
        self.slots[idx] = None

    def occupied(self) -> List[Tuple[int, Any]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        """True while any slot is occupied or requests are still queued."""
        return bool(self.queue) or any(s is not None for s in self.slots)


# ---------------------------------------------------------------------------
# Dynamic micro-batching (one-shot requests)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted request; ``result()`` blocks until served."""

    payload: Any
    bucket: Hashable
    enqueued_at: float
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    _value: Any = None
    _error: Optional[BaseException] = None
    latency_s: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._value


class MicroBatcher:
    """Dynamic micro-batcher: bucketed queue + size/timeout flush policy.

    process_fn(bucket_key, payloads) -> results
        Called on the worker thread with 1..max_batch_size payloads that share
        a bucket key; must return one result per payload, in order.
    bucket_fn(payload) -> hashable
        Bucket assignment (e.g. padded image shape); ``None`` puts everything
        in one bucket. Buckets never mix inside a batch.
    max_wait_s
        A non-full bucket flushes once its *oldest* request has waited this
        long; ``0`` flushes on every worker wakeup (latency-optimal).
    """

    def __init__(self, process_fn: Callable[[Hashable, List[Any]], List[Any]],
                 *, max_batch_size: int = 8, max_wait_s: float = 2e-3,
                 bucket_fn: Optional[Callable[[Any], Hashable]] = None,
                 metrics: Optional[ServingMetrics] = None,
                 clock=time.perf_counter):
        if max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {max_batch_size}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.process_fn = process_fn
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self.bucket_fn = bucket_fn or (lambda _payload: None)
        self.metrics = metrics or ServingMetrics()
        self._clock = clock
        self._cv = threading.Condition()
        self._buckets: Dict[Hashable, collections.deque] = {}
        self._running = False
        self._stopped = False
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "MicroBatcher":
        with self._cv:
            self._stopped = False
            if self._running:
                return self
            self._running = True
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="micro-batcher")
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker; by default serve everything still queued first.
        Further submissions raise until the batcher is start()ed again."""
        with self._cv:
            self._stopped = True
            was_running = self._running
            self._running = False
            self._cv.notify_all()
        if was_running:
            assert self._thread is not None
            self._thread.join()
            self._thread = None
        if drain:
            self._drain_inline()

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ----------------------------------------------------------

    def submit(self, payload: Any) -> Ticket:
        t = Ticket(payload=payload, bucket=self.bucket_fn(payload),
                   enqueued_at=self._clock())
        with self._cv:
            if self._stopped:
                # a post-stop ticket would sit in the queue forever (no
                # worker, no pending drain) — fail fast instead
                raise RuntimeError("MicroBatcher is stopped; call start()")
            self._buckets.setdefault(t.bucket, collections.deque()).append(t)
            depth = sum(len(q) for q in self._buckets.values())
            self._cv.notify_all()
        self.metrics.record_enqueue(depth)
        return t

    def submit_many(self, payloads: Iterable[Any]) -> List[Ticket]:
        return [self.submit(p) for p in payloads]

    @property
    def depth(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._buckets.values())

    @property
    def running(self) -> bool:
        with self._cv:
            return self._running

    # -- flush policy --------------------------------------------------------

    def _pop_ready_locked(self, now: float, drain: bool):
        """(bucket, tickets, reason) for the most urgent flushable bucket, or
        None. A bucket is flushable when full, expired, or draining; among
        flushable buckets the oldest head wins regardless of trigger, so a
        continuously-full hot bucket cannot starve an expired one past its
        max_wait_s."""
        best = None
        for key, q in self._buckets.items():
            if not q:
                continue
            head = q[0].enqueued_at
            if len(q) >= self.max_batch_size:
                reason = "size"
            elif now - head >= self.max_wait_s:
                reason = "timeout"
            elif drain:
                reason = "drain"
            else:
                continue
            if best is None or head < best[2]:
                best = (key, reason, head)
        if best is None:
            return None
        key, reason, _ = best
        q = self._buckets[key]
        batch = [q.popleft() for _ in range(min(self.max_batch_size, len(q)))]
        if not q:
            del self._buckets[key]
        return key, batch, reason

    def _next_deadline_locked(self) -> Optional[float]:
        heads = [q[0].enqueued_at for q in self._buckets.values() if q]
        return min(heads) + self.max_wait_s if heads else None

    # -- execution -----------------------------------------------------------

    def _serve(self, key: Hashable, batch: List[Ticket], reason: str) -> None:
        self.metrics.record_batch(len(batch), reason, self.max_batch_size)
        tracer = current_tracer()
        if tracer is not None:
            # retroactive span: the head ticket's time in queue. Only
            # meaningful when the batcher runs on the tracer's clock
            # (both default to time.perf_counter).
            head = min(t.enqueued_at for t in batch)
            tracer.event("batch.queue_wait", head, self._clock() - head,
                         "serving", bucket=str(key), size=len(batch),
                         reason=reason)
        try:
            with trace_span("batch.process", "serving", bucket=str(key),
                            size=len(batch), reason=reason):
                results = self.process_fn(key, [t.payload for t in batch])
            if len(results) != len(batch):
                raise RuntimeError(
                    f"process_fn returned {len(results)} results for "
                    f"{len(batch)} payloads (bucket {key!r})")
            errs = [None] * len(batch)
        except BaseException as e:  # noqa: BLE001 - propagate to each ticket
            results = [None] * len(batch)
            errs = [e] * len(batch)
        now = self._clock()
        depth = self.depth
        for t, r, e in zip(batch, results, errs):
            t._value, t._error = r, e
            t.latency_s = now - t.enqueued_at
            self.metrics.record_done(t.latency_s, ok=e is None, depth=depth)
            t._event.set()

    def _worker(self) -> None:
        while True:
            with self._cv:
                while True:
                    if not self._running:
                        return
                    now = self._clock()
                    ready = self._pop_ready_locked(now, drain=False)
                    if ready is not None:
                        break
                    deadline = self._next_deadline_locked()
                    timeout = None if deadline is None \
                        else max(0.0, deadline - now)
                    self._cv.wait(timeout)
            self._serve(*ready)

    def _drain_inline(self) -> None:
        """Serve every queued ticket on the calling thread (stop/flush)."""
        while True:
            with self._cv:
                ready = self._pop_ready_locked(self._clock(), drain=True)
            if ready is None:
                return
            self._serve(*ready)

    def flush(self) -> None:
        """Synchronously serve everything currently queued (testing/shutdown
        aid; safe while the worker runs — pops are mutually exclusive)."""
        self._drain_inline()
