"""Batched LM serving engine: prefill + greedy/temperature decode.

A single-process continuous-batching core: requests are padded into a fixed
batch, prefilled token-by-token through ``decode_step`` (uniform code path —
no separate prefill graph to keep per-request state simple), then decoded
until EOS/max_tokens. Per-slot state lives in the model's KV caches; the
queue/slot-refill bookkeeping is the shared
:class:`~repro.serving.batcher.SlotScheduler` (the same scheduling core the
vision micro-batcher builds on), and per-step occupancy plus per-request
latency land in a :class:`~repro.serving.metrics.ServingMetrics`.

For the large-scale path, the *dry-run* lowers the dedicated ``prefill``
graph (chunked attention, full-sequence); this engine is the functional
small-scale server used by the examples and tests.

The engine accepts a ``substrate`` override (a ``repro.nn.substrate`` spec)
so int8 / approximate-multiplier serving experiments run against the same
bundle + params without touching the model registry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import trace_span
from repro.serving.batcher import SlotScheduler
from repro.serving.metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, bundle, params, batch_size: int = 4,
                 max_len: int = 256, seed: int = 0, substrate=None,
                 metrics: Optional[ServingMetrics] = None):
        """substrate: optional ProductSubstrate spec string (e.g. ``"int8"``,
        ``"approx_lut:design_du2022"``) or instance overriding the bundle's
        ``cfg.dot_mode`` — the bundle is rebuilt on the overridden config so
        int8/approx serving experiments don't need a separate registry entry.
        Parameters are layout-compatible across substrates (the quantization
        boundary is dynamic), so the same ``params`` tree is served.
        metrics: optional shared :class:`ServingMetrics` (e.g. one backed by
        a shared registry for a combined export); a private one otherwise."""
        if substrate is not None:
            from repro.models import registry as reg
            from repro.nn import substrate as psub

            if isinstance(substrate, str):
                spec = substrate
            else:
                # the model path resolves by spec string (cfg.dot_mode), so a
                # substrate instance must be equivalent to what the registry
                # yields for its spec — a custom subclass would be silently
                # swapped out for the stock backend here
                spec = substrate.meta.spec
                stock = psub.get_substrate(spec)
                if type(stock) is not type(substrate) or \
                        stock.meta != substrate.meta:
                    raise ValueError(
                        f"substrate instance {substrate!r} does not match the "
                        f"registered backend for {spec!r}; pass a spec string "
                        "or register the backend first")
            bundle = reg.build_bundle(
                dataclasses.replace(bundle.cfg, dot_mode=spec))
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self._decode = jax.jit(bundle.decode_step)
        self._reset_state()

    def _reset_state(self):
        self.state = self.bundle.init_decode_state(self.batch, self.max_len)
        if self.cfg.family == "encdec":
            self.state["enc_out"] = jnp.zeros(
                (self.batch, self.cfg.n_frames, self.cfg.d_model), self.cfg.dtype)

    def _step(self, tokens: np.ndarray, cache_len: int):
        batch = {"token": jnp.asarray(tokens.reshape(self.batch, 1), jnp.int32),
                 "cache_len": jnp.asarray(cache_len, jnp.int32)}
        logits, self.state = self._decode(self.params, self.state, batch)
        return np.asarray(logits[:, 0, :], np.float32)

    def _sample(self, logits: np.ndarray, temps: np.ndarray) -> np.ndarray:
        out = np.empty(self.batch, np.int64)
        for i in range(self.batch):
            if temps[i] <= 0:
                out[i] = logits[i].argmax()
            else:
                z = logits[i] / temps[i]
                z -= z.max()
                p = np.exp(z)
                p /= p.sum()
                out[i] = self.rng.choice(len(p), p=p)
        return out

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests with continuous slot refill."""
        with trace_span("serve.generate", "serving", requests=len(requests)):
            return self._generate(requests)

    def _generate(self, requests: List[Request]) -> List[Request]:
        sched = SlotScheduler(self.batch)
        t_start = {}
        for r in requests:
            sched.submit(r)
            t_start[id(r)] = time.perf_counter()
            self.metrics.record_enqueue(len(sched.queue))

        # NOTE: the shared cache_len is the max over slots; per-slot masking
        # is handled by feeding pad tokens for idle slots (logits ignored).
        cache_len = 0
        served: set = set()                           # id(r) with metrics
        self._reset_state()
        cursor = np.zeros(self.batch, np.int64)       # prompt cursor
        while sched.busy and cache_len < self.max_len - 1:
            for i, r in sched.refill():
                if r.done:                           # e.g. re-submitted request
                    sched.release(i)
                    continue
                cursor[i] = 0                        # prompt starts here
            if not sched.occupancy:
                continue                             # nothing seated this step
            tokens = np.zeros(self.batch, np.int64)
            for i, r in sched.occupied():
                if r.done:
                    continue
                if cursor[i] < len(r.prompt):
                    tokens[i] = r.prompt[int(cursor[i])]
                elif r.output:
                    tokens[i] = r.output[-1]
            self.metrics.record_batch(sched.occupancy, "decode", self.batch)
            with trace_span("serve.decode_step", "serving",
                            cache_len=cache_len, occupancy=sched.occupancy):
                logits = self._step(tokens, cache_len)
            temps = np.array([r.temperature if r else 0.0 for r in sched.slots])
            nxt = self._sample(logits, temps)
            for i, r in sched.occupied():
                if r.done:
                    continue
                cursor[i] += 1
                if cursor[i] >= len(r.prompt):       # past prefill: emit
                    tok = int(nxt[i])
                    r.output.append(tok)
                    if (r.eos_id is not None and tok == r.eos_id) or \
                            len(r.output) >= r.max_tokens:
                        r.done = True
                        sched.release(i)
                        served.add(id(r))
                        self.metrics.record_done(
                            time.perf_counter() - t_start[id(r)],
                            depth=len(sched.queue))
            cache_len += 1
        for r in requests:
            r.done = True
            if id(r) not in served:  # truncated by max_len / never seated
                self.metrics.record_done(
                    time.perf_counter() - t_start[id(r)], ok=False, depth=0)
        return requests
