"""Batched LM serving engine: prefill + greedy/temperature decode.

A single-process continuous-batching core: requests are padded into a fixed
batch, prefilled token-by-token through ``decode_step`` (uniform code path —
no separate prefill graph to keep per-request state simple), then decoded
until EOS/max_tokens. Per-slot state lives in the model's KV caches; the
queue/slot-refill bookkeeping is the shared
:class:`~repro.serving.batcher.SlotScheduler` (the same scheduling core the
vision micro-batcher builds on), and per-step occupancy plus per-request
latency land in a :class:`~repro.serving.metrics.ServingMetrics`.

``generate(requests, workers=N)`` runs N concurrent decode loops, each with
its *own* KV caches, slot pool, and sampling RNG, all sharing the one
compiled ``decode_step`` (JAX compiled calls are thread-safe) and the one
metrics instance (``serving_worker_*`` families labeled ``lm-0..N-1``).
Requests split round-robin across loops. Greedy decodes of first-wave
requests (seated into fresh cache lanes) are bit-identical at every worker
count; a request seated into a *refilled* slot attends over the previous
occupant's cache prefix, so its tokens depend on scheduling order — a
pre-existing property of the shared-``cache_len`` engine that holds even
at ``workers=1`` (reordering requests changes refilled-slot outputs the
same way).

For the large-scale path, the *dry-run* lowers the dedicated ``prefill``
graph (chunked attention, full-sequence); this engine is the functional
small-scale server used by the examples and tests.

The engine accepts a ``substrate`` override — a ``repro.nn.substrate`` spec
or a per-site :class:`repro.nn.plan.SubstratePlan` — so int8 / approximate /
mixed-substrate serving experiments run against the same bundle + params
without touching the model registry.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import trace_span
from repro.serving.batcher import SlotScheduler
from repro.serving.metrics import ServingMetrics


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, bundle, params, batch_size: int = 4,
                 max_len: int = 256, seed: int = 0, substrate=None,
                 metrics: Optional[ServingMetrics] = None):
        """substrate: optional override for the bundle's substrate
        assignment — a ProductSubstrate spec string (e.g. ``"int8"``,
        ``"approx_lut:design_du2022"``), a ProductSubstrate instance, or a
        :class:`repro.nn.plan.SubstratePlan` (or its dict/JSON schema) for
        per-site mixed-substrate serving. The bundle is rebuilt on the
        overridden config (``cfg.dot_plan``), so int8/approx/mixed serving
        experiments don't need a separate registry entry. Parameters are
        layout-compatible across substrates (the quantization boundary is
        dynamic), so the same ``params`` tree is served.
        metrics: optional shared :class:`ServingMetrics` (e.g. one backed by
        a shared registry for a combined export); a private one otherwise."""
        if substrate is not None:
            from repro.models import registry as reg
            from repro.nn import plan as plan_mod
            from repro.nn import substrate as psub

            spec = None
            if isinstance(substrate, (plan_mod.SubstratePlan, dict)):
                plan = plan_mod.as_plan(substrate)
            elif isinstance(substrate, str):
                spec = substrate
                plan = plan_mod.SubstratePlan.uniform(substrate)
            else:
                # the model path resolves by spec string (cfg.dot_mode), so a
                # substrate instance must be equivalent to what the registry
                # yields for its spec — a custom subclass would be silently
                # swapped out for the stock backend here
                spec = substrate.meta.spec
                stock = psub.get_substrate(spec)
                if type(stock) is not type(substrate) or \
                        stock.meta != substrate.meta:
                    raise ValueError(
                        f"substrate instance {substrate!r} does not match the "
                        f"registered backend for {spec!r}; pass a spec string "
                        "or register the backend first")
                plan = plan_mod.SubstratePlan.uniform(spec)
            # uniform overrides mirror the spec into cfg.dot_mode too, so
            # introspection (and pre-plan callers) keep seeing the spec
            over = {"dot_plan": plan}
            if spec is not None:
                over["dot_mode"] = spec
            bundle = reg.build_bundle(
                dataclasses.replace(bundle.cfg, **over))
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self._decode = jax.jit(bundle.decode_step)
        self._reset_state()

    def _init_state(self):
        state = self.bundle.init_decode_state(self.batch, self.max_len)
        if self.cfg.family == "encdec":
            state["enc_out"] = jnp.zeros(
                (self.batch, self.cfg.n_frames, self.cfg.d_model), self.cfg.dtype)
        return state

    def _reset_state(self):
        self.state = self._init_state()

    def _step(self, state, tokens: np.ndarray, cache_len: int):
        batch = {"token": jnp.asarray(tokens.reshape(self.batch, 1), jnp.int32),
                 "cache_len": jnp.asarray(cache_len, jnp.int32)}
        logits, state = self._decode(self.params, state, batch)
        return np.asarray(logits[:, 0, :], np.float32), state

    def _sample(self, logits: np.ndarray, temps: np.ndarray,
                rng: np.random.Generator) -> np.ndarray:
        out = np.empty(self.batch, np.int64)
        for i in range(self.batch):
            if temps[i] <= 0:
                out[i] = logits[i].argmax()
            else:
                z = logits[i] / temps[i]
                z -= z.max()
                p = np.exp(z)
                p /= p.sum()
                out[i] = rng.choice(len(p), p=p)
        return out

    def generate(self, requests: List[Request],
                 workers: int = 1) -> List[Request]:
        """Serve a list of requests with continuous slot refill.

        ``workers > 1`` runs that many concurrent decode loops, each with
        its own KV caches and ``batch_size`` slots (requests split
        round-robin). Greedy outputs of first-wave requests are identical
        at any worker count (see the module docstring for the refilled-slot
        caveat).
        """
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        workers = min(workers, max(1, len(requests)))
        with trace_span("serve.generate", "serving", requests=len(requests),
                        workers=workers):
            if workers == 1:
                self._generate(requests, self.rng, worker="lm-0")
                return requests
            chunks = [requests[i::workers] for i in range(workers)]
            errors: List[BaseException] = []

            def run(i: int, chunk: List[Request]) -> None:
                try:
                    self._generate(chunk, np.random.default_rng(
                        (self.seed, i)), worker=f"lm-{i}")
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    errors.append(e)

            threads = [threading.Thread(target=run, args=(i, c),
                                        name=f"lm-decode-{i}")
                       for i, c in enumerate(chunks)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]
            return requests

    def _generate(self, requests: List[Request], rng: np.random.Generator,
                  worker: str = "lm-0") -> List[Request]:
        sched = SlotScheduler(self.batch)
        t_start = {}
        for r in requests:
            sched.submit(r)
            t_start[id(r)] = time.perf_counter()
            self.metrics.record_enqueue(len(sched.queue))

        # NOTE: the shared cache_len is the max over slots; per-slot masking
        # is handled by feeding pad tokens for idle slots (logits ignored).
        cache_len = 0
        served: set = set()                           # id(r) with metrics
        state = self._init_state()                    # this loop's KV caches
        cursor = np.zeros(self.batch, np.int64)       # prompt cursor
        while sched.busy and cache_len < self.max_len - 1:
            for i, r in sched.refill():
                if r.done:                           # e.g. re-submitted request
                    sched.release(i)
                    continue
                cursor[i] = 0                        # prompt starts here
            if not sched.occupancy:
                continue                             # nothing seated this step
            tokens = np.zeros(self.batch, np.int64)
            for i, r in sched.occupied():
                if r.done:
                    continue
                if cursor[i] < len(r.prompt):
                    tokens[i] = r.prompt[int(cursor[i])]
                elif r.output:
                    tokens[i] = r.output[-1]
            self.metrics.record_batch(sched.occupancy, "decode", self.batch)
            t_step = time.perf_counter()
            with trace_span("serve.decode_step", "serving",
                            cache_len=cache_len, occupancy=sched.occupancy,
                            worker=worker):
                logits, state = self._step(state, tokens, cache_len)
            self.metrics.record_worker_batch(
                worker, time.perf_counter() - t_step)
            temps = np.array([r.temperature if r else 0.0 for r in sched.slots])
            nxt = self._sample(logits, temps, rng)
            for i, r in sched.occupied():
                if r.done:
                    continue
                cursor[i] += 1
                if cursor[i] >= len(r.prompt):       # past prefill: emit
                    tok = int(nxt[i])
                    r.output.append(tok)
                    if (r.eos_id is not None and tok == r.eos_id) or \
                            len(r.output) >= r.max_tokens:
                        r.done = True
                        sched.release(i)
                        served.add(id(r))
                        self.metrics.record_done(
                            time.perf_counter() - t_start[id(r)],
                            depth=len(sched.queue))
            cache_len += 1
        for r in requests:
            r.done = True
            if id(r) not in served:  # truncated by max_len / never seated
                self.metrics.record_done(
                    time.perf_counter() - t_start[id(r)], ok=False, depth=0)
        return requests
