"""Edge-detection serving: dynamic micro-batching over the substrate registry.

:class:`EdgeDetectService` queues single uint8 images, buckets them by padded
shape, and drains each bucket through
:func:`repro.nn.conv.edge_detect_batched` on any registered
:class:`~repro.nn.substrate.ProductSubstrate` spec (``"approx_pallas"``,
``"approx_lut:design_du2022"``, ``"approx_pallas:csp_axc1@4"`` — the
Pallas path serves any wiring at widths 3..8 via the LUT kernel, …).

Bit-identity contract: a served edge map equals the direct
``edge_detect_batched(img[None], substrate)[0]`` exactly, for every
substrate. Padding preserves this because

* images are zero-embedded at the top-left of the bucket shape, which is
  indistinguishable (to the 'same'-convolution taps of every kept pixel)
  from the zero border padding the direct path applies, and
* every substrate contraction is row-independent over the im2col matrix
  (one row per output pixel), so extra pad rows/images never perturb kept
  pixels. Results are cropped back to the request shape.

Compiled-call caching: one jitted ``edge_detect_batched`` closure per
service (= per substrate), so JAX's jit cache keys compiles on the
(batch, H, W) abstract shape — a per-(shape, substrate) compiled-call
cache. The batch dimension is padded up to ``max_batch_size`` so occupancy
changes don't retrace, and the service tracks the shape keys it has seen
(``compiled_shapes``, ``metrics.compiled_calls``) to make the compile count
observable. The seen-shape set is lock-guarded so concurrent workers
hitting a new shape record exactly one compile (JAX's own jit cache already
serializes the compilation itself).

Multi-worker overlap: with ``n_workers > 1`` the service dispatches batch
``k+1`` while batch ``k`` still runs on the device — ``_process`` returns
the jitted call's result *without* materializing it (asynchronous JAX
dispatch) and ``_finalize`` defers the implicit ``block_until_ready`` (the
``np.asarray``) to result delivery. Every batch is still computed by the
same compiled call on the same padded operands, so served maps stay
bit-identical to the single-worker path on every substrate.
"""
from __future__ import annotations

import threading
import time
from typing import Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.nn import conv
from repro.nn import substrate as sub
from repro.obs.trace import trace_span
from repro.serving.batcher import MicroBatcher, Ticket
from repro.serving.metrics import ServingMetrics


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


class EdgeDetectService:
    """Micro-batched Laplacian edge detection on one product substrate
    (or a per-tap-group :class:`repro.nn.plan.SubstratePlan`).

    substrate:          spec string, ProductSubstrate instance, or a
                        :class:`~repro.nn.plan.SubstratePlan` (or its dict
                        schema) assigning specs to the edge tap-group sites
                        ``conv.edge.center`` / ``conv.edge.ring`` — plans
                        serve through :func:`repro.nn.conv.edge_detect_planned`
                        (uniform plans ≡ the direct path bit-identically).
    max_batch_size:     flush a shape bucket at this many images.
    max_wait_s:         flush a partial bucket once its oldest image has
                        waited this long.
    bucket_granularity: H and W are rounded up to this multiple to form the
                        bucket key (1 = exact-shape buckets, no padding).
    pad_batches:        pad the batch dim to max_batch_size before the
                        compiled call, so occupancy changes don't retrace.
    n_workers:          worker threads draining the bucketed queue; >1
                        overlaps host-side micro-batching with device
                        compute (results stay bit-identical).
    device_latency_s:   emulated extra device latency: a ``pure_callback``
                        sleep stage appended *inside* the compiled call, so
                        the batch occupies the (emulated) device for this
                        long after the real contraction — the full async
                        dispatch/finalize path is exercised while values
                        pass through unchanged. Lets a host-only runner
                        measure worker/overlap scaling as if the device were
                        this slow (benchmarks) and widens race windows
                        (stress tests). ``0`` (production default) adds no
                        stage.
    partitioning:       optional :class:`repro.nn.substrate.Partitioning` —
                        the served contraction lowers through shard_map
                        (data-parallel M / reduce-scattered K). Bit-identity
                        to the unsharded path holds for every bit-exact
                        substrate, so served maps are unchanged.
    """

    def __init__(self, substrate: "str | sub.ProductSubstrate" = "approx_bitexact",
                 *, max_batch_size: int = 8, max_wait_s: float = 2e-3,
                 bucket_granularity: int = 16, pad_batches: bool = True,
                 n_workers: int = 1, device_latency_s: float = 0.0,
                 partitioning: Optional[sub.Partitioning] = None,
                 metrics: Optional[ServingMetrics] = None, start: bool = True):
        if bucket_granularity < 1:
            raise ValueError(
                f"bucket_granularity must be >= 1, got {bucket_granularity}")
        if device_latency_s < 0:
            raise ValueError(
                f"device_latency_s must be >= 0, got {device_latency_s}")
        from repro.nn import plan as plan_mod
        if isinstance(substrate, (plan_mod.SubstratePlan, dict)):
            self.plan = plan_mod.as_plan(substrate)
            self.substrate = sub.get_substrate(self.plan.default)
            self.spec = self.plan.label
        else:
            self.plan = None
            self.substrate = sub.as_substrate(substrate)
            self.spec = self.substrate.meta.spec
        self.bucket_granularity = bucket_granularity
        self.pad_batches = pad_batches
        self.device_latency_s = device_latency_s
        self.partitioning = partitioning
        self.metrics = metrics or ServingMetrics()
        self._compiled_keys = set()  # (batch, H, W) shapes traced so far
        self._compiled_lock = threading.Lock()  # workers race on new shapes
        self._jit_fn = jax.jit(self._compute)
        self.batcher = MicroBatcher(
            self._process, max_batch_size=max_batch_size,
            max_wait_s=max_wait_s, bucket_fn=self._bucket,
            finalize_fn=self._finalize, n_workers=n_workers,
            metrics=self.metrics)
        if start:
            self.batcher.start()

    # -- lifecycle -----------------------------------------------------------

    def close(self, drain: bool = True) -> None:
        self.batcher.stop(drain=drain)

    def __enter__(self) -> "EdgeDetectService":
        self.batcher.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request path --------------------------------------------------------

    def _compute(self, batch):
        """Traced body of the compiled call: the edge-detect contraction,
        plus (when ``device_latency_s > 0``) an identity ``pure_callback``
        stage that holds the result on the emulated device for that long.
        The callback returns its input untouched, so emulation never
        perturbs served values — only their timing."""
        if self.plan is not None:
            out = conv.edge_detect_planned(
                batch, self.plan, partitioning=self.partitioning)
        else:
            out = conv.edge_detect_batched(
                batch, self.substrate, partitioning=self.partitioning)
        if self.device_latency_s > 0:
            out = jax.pure_callback(
                self._emulate_device,
                jax.ShapeDtypeStruct(out.shape, out.dtype), out)
        return out

    def _emulate_device(self, out):
        time.sleep(self.device_latency_s)
        return out

    def _bucket(self, img: np.ndarray) -> Tuple[int, int]:
        h, w = img.shape
        g = self.bucket_granularity
        return (_ceil_to(h, g), _ceil_to(w, g))

    def _process(self, bucket: Tuple[int, int], imgs: List[np.ndarray]):
        """Dispatch phase: pad to the bucket shape and enqueue the compiled
        call *without* blocking on it — the returned device array is
        materialized by :meth:`_finalize`, so with several workers the next
        batch's dispatch overlaps this batch's device compute."""
        hh, ww = bucket
        b = len(imgs)
        bp = self.batcher.max_batch_size if self.pad_batches else b
        with trace_span("edge.pad", "serving", bucket=f"{hh}x{ww}", size=b):
            batch = np.zeros((bp, hh, ww), np.uint8)
            for i, im in enumerate(imgs):
                h, w = im.shape
                batch[i, :h, :w] = im
        shape = "x".join(map(str, batch.shape))
        with self._compiled_lock:
            first = batch.shape not in self._compiled_keys
            if first:
                self._compiled_keys.add(batch.shape)
        if first:
            self.metrics.record_compile()
            # first call for this shape: the jitted call traces + compiles
            # before dispatching, so this span is compile-dominated
            with trace_span("edge.compile", "serving", shape=shape,
                            spec=self.spec):
                out = self._jit_fn(batch)
        else:
            with trace_span("edge.execute", "serving", shape=shape,
                            spec=self.spec):
                out = self._jit_fn(batch)
        return out, [im.shape for im in imgs]

    def _finalize(self, bucket: Tuple[int, int], raw) -> List[np.ndarray]:
        """Delivery phase: block until the dispatched batch is ready, then
        crop each map back to its request shape."""
        out_dev, shapes = raw
        with trace_span("edge.wait", "serving", size=len(shapes)):
            out = np.asarray(out_dev)      # implicit block_until_ready
        with trace_span("edge.crop", "serving", size=len(shapes)):
            return [out[i, :h, :w] for i, (h, w) in enumerate(shapes)]

    @staticmethod
    def _check_image(img) -> np.ndarray:
        a = np.asarray(img)
        if a.ndim != 2 or a.dtype != np.uint8:
            raise ValueError(
                f"expected a single (H, W) uint8 image, got {a.dtype} "
                f"array of shape {a.shape}")
        return a

    def submit(self, img: np.ndarray) -> Ticket:
        """Queue one (H, W) uint8 image; returns a Ticket (``.result()``)."""
        return self.batcher.submit(self._check_image(img))

    def detect(self, imgs: "np.ndarray | Iterable[np.ndarray]",
               timeout: Optional[float] = 60.0) -> List[np.ndarray]:
        """Submit image(s) and block for the edge maps, preserving order.

        Accepts one (H, W) image, a (B, H, W) stack, or an iterable of
        arbitrary-shape (H, W) images (exercises the bucketing path).
        """
        if isinstance(imgs, np.ndarray) and imgs.ndim == 2:
            imgs = [imgs]
        tickets = self.batcher.submit_many(
            self._check_image(im) for im in imgs)
        if not self.batcher.running:
            self.batcher.flush()
        return [t.result(timeout=timeout) for t in tickets]

    # -- introspection -------------------------------------------------------

    @property
    def n_workers(self) -> int:
        return self.batcher.n_workers

    @property
    def compiled_shapes(self) -> Sequence[Tuple[int, int, int]]:
        """(batch, H, W) keys the service has compiled calls for."""
        with self._compiled_lock:
            return tuple(sorted(self._compiled_keys))

    def stats(self) -> dict:
        return self.metrics.snapshot()
