"""Optimizers + schedules + gradient utilities (pure-JAX, sharding-aware)."""
from repro.optim.adafactor import adafactor  # noqa: F401
from repro.optim.adamw import adamw  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
