"""AdamW with decoupled weight decay.

State layout mirrors the param tree (each leaf becomes {"m": ..., "v": ...})
so the dry-run's name-based sharding rules apply to optimizer state
transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        def leaf(p):
            return {"m": jnp.zeros(p.shape, moment_dtype),
                    "v": jnp.zeros(p.shape, moment_dtype)}
        return {"step": jnp.zeros((), jnp.int32),
                "mv": jax.tree_util.tree_map(leaf, params)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def leaf(g, mv, p):
            g32 = g.astype(moment_dtype)
            m = b1 * mv["m"] + (1 - b1) * g32
            v = b2 * mv["v"] + (1 - b2) * jnp.square(g32)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            newp = p.astype(jnp.float32) - lr * (upd.astype(jnp.float32)
                                                 + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), {"m": m, "v": v}

        flat = jax.tree_util.tree_map(
            leaf, grads, state["mv"], params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) == {"m", "v"})
        new_params = jax.tree_util.tree_map(
            lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mv = jax.tree_util.tree_map(
            lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "mv": new_mv}

    return Optimizer(init, update)
