"""Adafactor (factored second moments) — the memory-sane optimizer for the
trillion-parameter MoE configs (m: optional momentum off by default).

State per >=2-D leaf: {"vr": shape[:-1], "vc": shape[:-2] + shape[-1:]};
1-D leaves fall back to a full second moment {"v": shape}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mv": jax.tree_util.tree_map(leaf, params)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def leaf(g, mv, p):
            # second-moment statistics in f32 (fused square+mean reductions);
            # the big elementwise update path stays in the gradient dtype —
            # halves peak optimizer temporaries on trillion-param leaves
            if p.ndim >= 2:
                vr = beta * mv["vr"] + (1 - beta) * (
                    jnp.square(g.astype(jnp.float32)).mean(axis=-1) + eps)
                vc = beta * mv["vc"] + (1 - beta) * (
                    jnp.square(g.astype(jnp.float32)).mean(axis=-2) + eps)
                denom = vr[..., None] * vc[..., None, :] / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], eps)
                scale = jax.lax.rsqrt(denom + eps).astype(g.dtype)
                upd = g * scale
                new_mv = {"vr": vr, "vc": vc}
            else:
                v = beta * mv["v"] + (1 - beta) * (
                    jnp.square(g.astype(jnp.float32)) + eps)
                upd = g * jax.lax.rsqrt(v + eps).astype(g.dtype)
                new_mv = {"v": v}
            # update clipping (RMS_threshold = 1.0; reduction in f32)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd.astype(jnp.float32))) + eps)
            clip = (1.0 / jnp.maximum(1.0, rms / clip_threshold)).astype(jnp.float32)
            newp = (p.astype(jnp.float32)
                    - lr * clip * upd.astype(jnp.float32)).astype(p.dtype)
            return newp, new_mv

        flat = jax.tree_util.tree_map(
            leaf, grads, state["mv"], params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) <= {"vr", "vc", "v"})
        new_params = jax.tree_util.tree_map(
            lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mv = jax.tree_util.tree_map(
            lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "mv": new_mv}

    return Optimizer(init, update)
