"""Gradient utilities: global-norm clipping and int8 gradient compression.

Compression follows the paper's quantization theme: gradients are
symmetrically quantized to int8 *before* the data-parallel all-reduce and
dequantized after — an 8× reduction in gradient all-reduce bytes. Used by
the shard_map data-parallel step in ``repro.train.loop`` (the pjit path
reduces implicitly, so compression is expressed where the collective is
explicit).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def compress_int8(g: jnp.ndarray):
    """Symmetric absmax int8 quantization of one gradient leaf."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(tree, axis_name: str):
    """int8-compressed gradient all-reduce (inside shard_map).

    Quantize per-leaf → psum int32 (exact integer accumulation) → dequantize
    with the max scale (scales are psum-maxed so dequantization is
    consistent across shards).
    """
    def leaf(g):
        q, scale = compress_int8(g)
        # share a common scale (max over shards) so the int sum is coherent
        smax = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / smax), -127, 127
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * smax / n).astype(g.dtype)

    return jax.tree_util.tree_map(leaf, tree)
