"""Decoder-only LM family.

Covers: llama4-maverick (interleaved MoE top-1 + shared expert), kimi-k2
(all-MoE top-8 + shared expert), internlm2, qwen1.5 (qkv bias), gemma3
(5:1 local:global attention), minitron, and the paligemma VLM backbone
(prefix patch embeddings).

Layers are grouped into a repeating *unit* (period = lcm of the MoE
interleave and the local:global pattern); params are stacked over unit
repeats and applied under ``lax.scan`` so a 61-layer 1T-param model lowers
to one unit's HLO.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import sharding as sh
from repro.nn import plan as splan

Array = jnp.ndarray
Params = Dict[str, Any]


def layer_plan(cfg: cm.ModelConfig) -> List[Dict]:
    """Per-layer block descriptors: {'moe': bool, 'window': int}."""
    plan = []
    for i in range(cfg.n_layers):
        moe = cfg.n_experts > 0 and (i % cfg.moe_interleave == cfg.moe_interleave - 1)
        window = 0
        if cfg.local_global_ratio > 0:
            # pattern: R local layers then 1 global
            window = cfg.local_window if (i % (cfg.local_global_ratio + 1)
                                          != cfg.local_global_ratio) else 0
        plan.append({"moe": moe, "window": window})
    return plan


def unit_period(cfg: cm.ModelConfig) -> int:
    p = 1
    if cfg.n_experts:
        p = max(p, cfg.moe_interleave)
    if cfg.local_global_ratio:
        p = _lcm(p, cfg.local_global_ratio + 1)
    return p


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: cm.ModelConfig, desc: Dict) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"attn": cm.init_attn(k1, cfg)}
    if desc["moe"]:
        p["moe"] = cm.init_moe(k2, cfg)
    else:
        p["ffn"] = cm.init_ffn(k2, cfg)
    return p


def init_params(cfg: cm.ModelConfig, rng: Array) -> Params:
    plan = layer_plan(cfg)
    period = unit_period(cfg)
    n_units = cfg.n_layers // period
    tail = plan[n_units * period:]

    keys = jax.random.split(rng, 2 + period + len(tail))
    params: Params = {"embed": cm.init_embed(keys[0], cfg)}

    # stacked unit params: for each in-unit position u, stack over repeats
    unit = []
    for u in range(period if n_units else 0):
        desc = plan[u]

        def init_one(k, _desc=desc):
            return _init_layer(k, cfg, _desc)

        per_repeat = jax.vmap(init_one)(
            jax.random.split(keys[1 + u], n_units)
        )
        unit.append(per_repeat)
    params["unit"] = unit
    params["tail"] = [
        _init_layer(keys[1 + period + i], cfg, d) for i, d in enumerate(tail)
    ]
    if cfg.family == "vlm":
        params["patch_proj"] = cm.init_dense(keys[-1], cfg.d_model, cfg.d_model, cfg.dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_layer(cfg, p, x, desc, positions, kv_cache=None, cache_len=None):
    x, new_cache = cm.attn_block(
        cfg, p["attn"], x, positions=positions, window=desc["window"],
        kv_cache=kv_cache, cache_len=cache_len,
    )
    if desc["moe"]:
        x = cm.moe_block(cfg, p["moe"], x)
    else:
        x = cm.ffn_block(cfg, p["ffn"], x)
    return x, new_cache


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def forward(cfg: cm.ModelConfig, params: Params, tokens: Array,
            patch_embeds: Optional[Array] = None) -> Array:
    """Full-sequence forward → final hidden states (B, S, d)."""
    plan = layer_plan(cfg)
    period = unit_period(cfg)
    n_units = cfg.n_layers // period

    x = cm.embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = cm.dense(cfg, patch_embeds.astype(x.dtype),
                      params["patch_proj"]["w"], site="patch_proj")
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    # per-repeat site names for in-unit position u: layer index r*period+u
    site_names = [[f"layer.{r * period + u}" for r in range(n_units)]
                  for u in range(period)]

    def unit_body(xc, xs):
        unit_params, repeat = xs
        for u in range(period):
            def one(xx, pp=unit_params[u], desc=plan[u], names=site_names[u]):
                with splan.scan_site_scope(repeat, names):
                    y, _ = _apply_layer(cfg, pp, xx, desc, positions)
                return y
            xc = _maybe_remat(cfg, one)(xc)
        return xc, None

    if n_units:
        x, _ = jax.lax.scan(unit_body, x,
                            (_stack_unit(params["unit"]),
                             jnp.arange(n_units)))
    for i, p in enumerate(params["tail"]):
        desc = plan[n_units * period + i]
        with splan.site_scope(f"layer.{n_units * period + i}"):
            x, _ = _apply_layer(cfg, p, x, desc, positions)
    return x


def _stack_unit(unit_list):
    """list (per in-unit position) of stacked pytrees -> scan-compatible xs."""
    return tuple(unit_list)


def loss_fn(cfg: cm.ModelConfig, params: Params, batch: Dict[str, Array]) -> Array:
    x = forward(cfg, params, batch["tokens"], batch.get("patch_embeds"))
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1]:]  # loss on text positions only
    return cm.lm_loss_chunked(cfg, params["embed"], x, labels)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV caches
# ---------------------------------------------------------------------------


def init_kv_caches(cfg: cm.ModelConfig, batch: int, max_len: int) -> List:
    """Stacked per-unit-position caches + tail caches."""
    period = unit_period(cfg)
    n_units = cfg.n_layers // period
    plan = layer_plan(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.dh

    def mk(shape):
        z = jnp.zeros(shape, cfg.dtype)
        return z

    unit_caches = [
        (mk((n_units, batch, max_len, hkv, dh)), mk((n_units, batch, max_len, hkv, dh)))
        for _ in range(period)
    ]
    tail_caches = [
        (mk((batch, max_len, hkv, dh)), mk((batch, max_len, hkv, dh)))
        for _ in plan[n_units * period:]
    ]
    return {"unit": unit_caches, "tail": tail_caches}


def decode_step(cfg: cm.ModelConfig, params: Params, caches, token: Array,
                cache_len: Array) -> Tuple[Array, Any]:
    """One decode step: token (B, 1) int32 → logits (B, 1, V), new caches."""
    plan = layer_plan(cfg)
    period = unit_period(cfg)
    n_units = cfg.n_layers // period

    x = cm.embed(cfg, params["embed"], token)
    b = x.shape[0]
    positions = jnp.broadcast_to(cache_len, (b, 1)).astype(jnp.int32)

    site_names = [[f"layer.{r * period + u}" for r in range(n_units)]
                  for u in range(period)]

    new_unit_caches = []
    if n_units:
        def unit_body(xc, xs):
            unit_params, unit_cache, repeat = xs
            new_caches_u = []
            for u in range(period):
                with splan.scan_site_scope(repeat, site_names[u]):
                    y, nc = _apply_layer(cfg, unit_params[u], xc, plan[u],
                                         positions, kv_cache=unit_cache[u],
                                         cache_len=cache_len)
                new_caches_u.append(nc)
                xc = y
            return xc, tuple(new_caches_u)

        x, new_unit = jax.lax.scan(
            unit_body, x, (_stack_unit(params["unit"]),
                           tuple(caches["unit"]), jnp.arange(n_units))
        )
        new_unit_caches = list(new_unit)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        desc = plan[n_units * period + i]
        with splan.site_scope(f"layer.{n_units * period + i}"):
            x, nc = _apply_layer(cfg, p, x, desc, positions,
                                 kv_cache=caches["tail"][i],
                                 cache_len=cache_len)
        new_tail.append(nc)
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits, {"unit": new_unit_caches, "tail": new_tail}


def prefill(cfg: cm.ModelConfig, params: Params, tokens: Array,
            patch_embeds: Optional[Array] = None) -> Array:
    """Prefill forward: returns last-position logits (caches implicit —
    the dry-run lowers the compute; a serving engine would also emit KV)."""
    x = forward(cfg, params, tokens, patch_embeds)
    return cm.lm_logits(cfg, params["embed"], x[:, -1:, :])
