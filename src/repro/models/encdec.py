"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d) — the output of the
two-conv downsampling stack. The transformer backbone is real: a
non-causal encoder (scan over layers) and a causal decoder with
self-attention + cross-attention + FFN per layer.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.nn import plan as splan

Array = jnp.ndarray
Params = Dict[str, Any]


def init_params(cfg: cm.ModelConfig, rng: Array) -> Params:
    ne = cfg.n_encoder_layers or cfg.n_layers
    k_enc, k_dec, k_emb, k_x = jax.random.split(rng, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": cm.init_attn(k1, cfg), "ffn": cm.init_ffn(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self": cm.init_attn(k1, cfg), "cross": cm.init_attn(k2, cfg),
                "ffn": cm.init_ffn(k3, cfg)}

    enc = jax.vmap(enc_layer)(jax.random.split(k_enc, ne))
    dec = jax.vmap(dec_layer)(jax.random.split(k_dec, cfg.n_layers))
    return {"embed": cm.init_embed(k_emb, cfg), "enc": enc, "dec": dec}


def encode(cfg: cm.ModelConfig, params: Params, frames: Array) -> Array:
    """frames: (B, n_frames, d) stub embeddings → encoder states."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = frames.astype(cfg.dtype)

    ne = jax.tree_util.tree_leaves(params["enc"])[0].shape[0]
    enc_sites = [f"enc.{i}" for i in range(ne)]

    def body(xc, xs):
        p, li = xs

        def one(xx):
            with splan.scan_site_scope(li, enc_sites):
                y, _ = cm.attn_block(cfg, p["attn"], xx, positions=positions,
                                     causal=False)
                return cm.ffn_block(cfg, p["ffn"], y)
        return (jax.checkpoint(one)(xc) if cfg.remat else one(xc)), None

    x, _ = jax.lax.scan(body, x, (params["enc"], jnp.arange(ne)))
    return x


def decode_train(cfg: cm.ModelConfig, params: Params, tokens: Array,
                 enc_out: Array) -> Array:
    x = cm.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    dec_sites = [f"dec.{i}" for i in range(cfg.n_layers)]

    def body(xc, xs):
        p, li = xs

        def one(xx):
            with splan.scan_site_scope(li, dec_sites):
                with splan.site_scope("self"):
                    y, _ = cm.attn_block(cfg, p["self"], xx,
                                         positions=positions)
                # cross attention: K/V from encoder output through this
                # layer's projections
                hkv, dh = cfg.n_kv_heads, cfg.dh
                be, se, _ = enc_out.shape
                with splan.site_scope("cross"):
                    ck = cm.dense(cfg, enc_out, p["cross"]["wk"]["w"],
                                  site="wk").reshape(be, se, hkv, dh)
                    cv = cm.dense(cfg, enc_out, p["cross"]["wv"]["w"],
                                  site="wv").reshape(be, se, hkv, dh)
                    y, _ = cm.attn_block(cfg, p["cross"], y,
                                         positions=positions,
                                         cross_kv=(ck, cv))
                return cm.ffn_block(cfg, p["ffn"], y)
        return (jax.checkpoint(one)(xc) if cfg.remat else one(xc)), None

    x, _ = jax.lax.scan(body, x, (params["dec"], jnp.arange(cfg.n_layers)))
    return x


def loss_fn(cfg: cm.ModelConfig, params: Params, batch: Dict[str, Array]) -> Array:
    enc_out = encode(cfg, params, batch["frames"])
    x = decode_train(cfg, params, batch["tokens"], enc_out)
    return cm.lm_loss_chunked(cfg, params["embed"], x, batch["labels"])


def init_kv_caches(cfg: cm.ModelConfig, batch: int, max_len: int):
    hkv, dh = cfg.n_kv_heads, cfg.dh
    z = lambda: jnp.zeros((cfg.n_layers, batch, max_len, hkv, dh), cfg.dtype)
    return {"self_kv": (z(), z())}


def decode_step(cfg: cm.ModelConfig, params: Params, state, token: Array,
                cache_len: Array):
    """One decoder token; cross-attends to precomputed encoder states.

    state: {"self_kv": stacked caches, "enc_out": (B, frames, d)}.
    """
    x = cm.embed(cfg, params["embed"], token)
    b = x.shape[0]
    positions = jnp.broadcast_to(cache_len, (b, 1)).astype(jnp.int32)
    enc_out = state["enc_out"]
    hkv, dh = cfg.n_kv_heads, cfg.dh
    be, se, _ = enc_out.shape

    dec_sites = [f"dec.{i}" for i in range(cfg.n_layers)]

    def body(xc, xs):
        p, kv, li = xs
        with splan.scan_site_scope(li, dec_sites):
            with splan.site_scope("self"):
                y, nkv = cm.attn_block(cfg, p["self"], xc,
                                       positions=positions,
                                       kv_cache=kv, cache_len=cache_len)
            with splan.site_scope("cross"):
                ck = cm.dense(cfg, enc_out, p["cross"]["wk"]["w"],
                              site="wk").reshape(be, se, hkv, dh)
                cv = cm.dense(cfg, enc_out, p["cross"]["wv"]["w"],
                              site="wv").reshape(be, se, hkv, dh)
                y, _ = cm.attn_block(cfg, p["cross"], y, positions=positions,
                                     cross_kv=(ck, cv))
            y = cm.ffn_block(cfg, p["ffn"], y)
        return y, nkv

    x, new_kv = jax.lax.scan(
        body, x, (params["dec"], state["self_kv"], jnp.arange(cfg.n_layers)))
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits, {"self_kv": new_kv, "enc_out": enc_out}


def prefill(cfg: cm.ModelConfig, params: Params, tokens: Array,
            frames: Array) -> Array:
    enc_out = encode(cfg, params, frames)
    x = decode_train(cfg, params, tokens, enc_out)
    return cm.lm_logits(cfg, params["embed"], x[:, -1:, :])
