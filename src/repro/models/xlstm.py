"""xLSTM family (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

mLSTM: matrix memory C ∈ R^{dh×dh} per head with exponential-style gating,
run as a chunked recurrence (state carried across chunks, intra-chunk
parallel quadratic form — the linear-attention identity).
sLSTM: per-head vector memory with sigmoid gates (chunk-scanned GRU-like
recurrence).

Both are sub-quadratic in sequence length with O(1) decode state — this is
the arch family that serves the ``long_500k`` shape.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import sharding as sh
from repro.nn import plan as splan

Array = jnp.ndarray
Params = Dict[str, Any]


def _split_heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: cm.ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "wq": cm.init_dense(ks[0], d, d, cfg.dtype),
        "wk": cm.init_dense(ks[1], d, d, cfg.dtype),
        "wv": cm.init_dense(ks[2], d, d, cfg.dtype),
        "wi": cm.init_dense(ks[3], d, cfg.n_heads, cfg.dtype),   # input gate
        "wf": cm.init_dense(ks[4], d, cfg.n_heads, cfg.dtype),   # forget gate
        "wo_gate": cm.init_dense(ks[5], d, d, cfg.dtype),
        "wo": cm.init_dense(ks[6], d, d, cfg.dtype),
    }


def mlstm_scan(q, k, v, i_gate, f_gate, state, chunk: int, unroll: bool = False):
    """Chunked linear-attention recurrence.

    q,k,v: (B,S,H,dh); i_gate/f_gate: (B,S,H) in (0,1);
    state: (B,H,dh,dh) carried matrix memory. Returns (y, new_state).
    """
    b, s, h, dh = q.shape
    chunk = min(chunk, s)
    n = s // chunk
    assert n * chunk == s, "sequence must be divisible by chunk"

    qc = q.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    ic = i_gate.reshape(b, n, chunk, h).transpose(1, 0, 2, 3)
    fc = f_gate.reshape(b, n, chunk, h).transpose(1, 0, 2, 3)

    def body(carry, xs):
        st = carry                                  # (B,H,dh,dh)
        qq, kk, vv, ii, ff = xs
        logf = jnp.log(jnp.maximum(ff.astype(jnp.float32), 1e-6))
        lcum = jnp.cumsum(logf, axis=1)             # (B,C,H)
        # intra-chunk: M[t,u] = exp(lcum_t - lcum_u) * i_u * (q_t · k_u), u<=t
        qt = qq.astype(jnp.float32) * jnp.exp(lcum)[..., None]
        ku = kk.astype(jnp.float32) * (ii.astype(jnp.float32)
                                       * jnp.exp(-lcum))[..., None]
        scores = jnp.einsum("bthd,buhd->bhtu", qt, ku)
        mask = jnp.tril(jnp.ones((qq.shape[1], qq.shape[1]), bool))
        scores = jnp.where(mask[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhtu,buhd->bthd", scores, vv.astype(jnp.float32))
        # inter-chunk: y_t += exp(lcum_t) * q_t @ state
        y_inter = jnp.einsum("bthd,bhde->bthe", qt, st)
        # state update: st' = exp(lcum_C) * st + sum_u exp(lcum_C - lcum_u) i_u k_u v_u^T
        decay_all = jnp.exp(lcum[:, -1:, :])        # (B,1,H)
        ku_tail = kk.astype(jnp.float32) * (
            ii.astype(jnp.float32) * jnp.exp(lcum[:, -1:, :] - lcum))[..., None]
        st_new = st * decay_all[:, 0, :, None, None] + jnp.einsum(
            "buhd,buhe->bhde", ku_tail, vv.astype(jnp.float32))
        return st_new, (y_intra + y_inter)

    state, ys = jax.lax.scan(jax.checkpoint(body), state, (qc, kc, vc, ic, fc),
                             unroll=n if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return y, state


def mlstm_block(cfg: cm.ModelConfig, p: Params, x: Array,
                state=None) -> Tuple[Array, Array]:
    b, s, d = x.shape
    h = cfg.n_heads
    xn = cm.rms_norm(x, p["ln"])
    q = _split_heads(cm.dense(cfg, xn, p["wq"]["w"], site="wq"), h) / math.sqrt(d // h)
    k = _split_heads(cm.dense(cfg, xn, p["wk"]["w"], site="wk"), h)
    v = _split_heads(cm.dense(cfg, xn, p["wv"]["w"], site="wv"), h)
    i_gate = jax.nn.sigmoid(cm.dense(cfg, xn, p["wi"]["w"], site="wi").astype(jnp.float32))
    f_gate = jax.nn.sigmoid(cm.dense(cfg, xn, p["wf"]["w"], site="wf").astype(jnp.float32) + 3.0)
    if state is None:
        state = jnp.zeros((b, h, d // h, d // h), jnp.float32)
    y, new_state = mlstm_scan(q, k, v, i_gate, f_gate, state,
                              chunk=min(cfg.attn_chunk, s),
                              unroll=cfg.cost_unroll)
    y = y.reshape(b, s, d).astype(x.dtype)
    gate = jax.nn.sigmoid(cm.dense(cfg, xn, p["wo_gate"]["w"], site="wo_gate").astype(jnp.float32))
    y = (y.astype(jnp.float32) * gate).astype(x.dtype)
    return x + cm.dense(cfg, y, p["wo"]["w"], site="wo").astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# sLSTM block (vector memory, chunk-scanned)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: cm.ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "wz": cm.init_dense(ks[0], d, d, cfg.dtype),
        "wi": cm.init_dense(ks[1], d, d, cfg.dtype),
        "wf": cm.init_dense(ks[2], d, d, cfg.dtype),
        "wo_gate": cm.init_dense(ks[3], d, d, cfg.dtype),
        "wo": cm.init_dense(ks[4], d, d, cfg.dtype),
    }


def slstm_block(cfg: cm.ModelConfig, p: Params, x: Array,
                state=None) -> Tuple[Array, Array]:
    b, s, d = x.shape
    xn = cm.rms_norm(x, p["ln"])
    z = jnp.tanh(cm.dense(cfg, xn, p["wz"]["w"], site="wz").astype(jnp.float32))
    i = jax.nn.sigmoid(cm.dense(cfg, xn, p["wi"]["w"], site="wi").astype(jnp.float32))
    f = jax.nn.sigmoid(cm.dense(cfg, xn, p["wf"]["w"], site="wf").astype(jnp.float32) + 2.0)
    if state is None:
        state = jnp.zeros((b, d), jnp.float32)

    # c_t = f_t c_{t-1} + i_t z_t  — associative scan over time (log-space-free:
    # the pair (f, i·z) composes as (f1f2, f2 b1 + b2))
    def compose(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_seq = f.transpose(1, 0, 2)                     # (S, B, d)
    b_seq = (i * z).transpose(1, 0, 2)
    # fold the carried state into the first element
    b_seq = b_seq.at[0].add(a_seq[0] * state)
    a_cum, c_seq = jax.lax.associative_scan(compose, (a_seq, b_seq))
    c = c_seq.transpose(1, 0, 2)                     # (B, S, d)
    new_state = c_seq[-1]
    o = jax.nn.sigmoid(cm.dense(cfg, xn, p["wo_gate"]["w"], site="wo_gate").astype(jnp.float32))
    y = (o * jnp.tanh(c)).astype(x.dtype)
    return x + cm.dense(cfg, y, p["wo"]["w"], site="wo").astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------


def _kind(i: int) -> str:
    return "m" if i % 2 == 0 else "s"


def init_params(cfg: cm.ModelConfig, rng: Array) -> Params:
    keys = jax.random.split(rng, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        init = init_mlstm if _kind(i) == "m" else init_slstm
        layers.append(init(keys[i], cfg))
    return {"embed": cm.init_embed(keys[-1], cfg), "layers": layers}


def forward(cfg: cm.ModelConfig, params: Params, tokens: Array) -> Array:
    x = cm.embed(cfg, params["embed"], tokens)
    for i, layer in enumerate(params["layers"]):
        block = mlstm_block if _kind(i) == "m" else slstm_block
        kind = "mlstm" if _kind(i) == "m" else "slstm"

        def fn(xx, pp=layer, blk=block, scope=(f"layer.{i}", kind)):
            with splan.site_scope(*scope):
                return blk(cfg, pp, xx)[0]
        x = jax.checkpoint(fn)(x) if cfg.remat else fn(x)
    return x


def loss_fn(cfg: cm.ModelConfig, params: Params, batch: Dict[str, Array]) -> Array:
    x = forward(cfg, params, batch["tokens"])
    return cm.lm_loss_chunked(cfg, params["embed"], x, batch["labels"])


def init_decode_state(cfg: cm.ModelConfig, batch: int):
    states = []
    d, h = cfg.d_model, cfg.n_heads
    for i in range(cfg.n_layers):
        if i % 2 == 0:
            states.append(jnp.zeros((batch, h, d // h, d // h), jnp.float32))
        else:
            states.append(jnp.zeros((batch, d), jnp.float32))
    return states


def decode_step(cfg: cm.ModelConfig, params: Params, states, token: Array,
                cache_len: Array):
    """O(1)-state decode: one token through all recurrent blocks."""
    x = cm.embed(cfg, params["embed"], token)
    new_states = []
    for i, (layer, st) in enumerate(zip(params["layers"], states)):
        block = mlstm_block if _kind(i) == "m" else slstm_block
        kind = "mlstm" if _kind(i) == "m" else "slstm"
        with splan.site_scope(f"layer.{i}", kind):
            x, ns = block(cfg, layer, x, state=st)
        new_states.append(ns)
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits, new_states


def prefill(cfg: cm.ModelConfig, params: Params, tokens: Array) -> Array:
    x = forward(cfg, params, tokens)
    return cm.lm_logits(cfg, params["embed"], x[:, -1:, :])
