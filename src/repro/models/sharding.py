"""Logical-axis sharding: one model code path for 1-device smoke tests and
512-device dry-runs.

Model code annotates tensors with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); a rules table maps logical names
to mesh axes. Outside a Mesh context (smoke tests) the annotation is a no-op.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),     # batch parallel across pods × data axis
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_cap": ("pod", "data"),
    "tokens": ("pod", "data"),    # flattened batch*seq rows
    "kv_seq": None,
    "conv_w": None,
    "state": None,
    "frames": None,
}


def set_rules(rules: dict | None) -> None:
    _state.rules = rules


def get_rules() -> dict:
    return getattr(_state, "rules", None) or DEFAULT_RULES


def current_mesh() -> Optional[Mesh]:
    env = jax._src.mesh.thread_resources.env  # set by `with mesh:`
    m = env.physical_mesh
    return None if m.empty else m


def _resolve(axis_name: Optional[str], mesh: Mesh) -> Optional[object]:
    if axis_name is None:
        return None
    rule = get_rules().get(axis_name, None)
    if rule is None:
        return None
    names = rule if isinstance(rule, tuple) else (rule,)
    present = tuple(n for n in names if n in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def spec(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for logical axes under the current mesh (or empty)."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    resolved = []
    used: set = set()
    for ax in logical_axes:
        r = _resolve(ax, mesh)
        # a mesh axis may appear at most once in a PartitionSpec
        if r is not None:
            rs = r if isinstance(r, tuple) else (r,)
            if any(x in used for x in rs):
                r = None
            else:
                used.update(rs)
        resolved.append(r)
    return P(*resolved)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under a mesh; identity otherwise.

    Axes whose size does not divide the mesh-axis product are left
    unsharded (GSPMD would otherwise pad-and-shard, which is rarely wanted
    for head counts like kv=8 on a 16-way model axis).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = list(spec(*logical_axes))
    # divisibility check
    for i, r in enumerate(resolved):
        if r is None:
            continue
        names = r if isinstance(r, tuple) else (r,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if i < x.ndim and x.shape[i] % size != 0:
            resolved[i] = None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*logical_axes))
