"""Architecture registry: config → init / loss / prefill / decode builders.

Every assigned architecture registers here; ``--arch <id>`` in the launchers
resolves through this table. Also provides ``input_specs`` —
ShapeDtypeStruct stand-ins for every model input per (arch × shape), used
by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import encdec, lm, xlstm, zamba
from repro.nn import substrate as psub

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs allowed to run long_500k (sub-quadratic); see DESIGN.md §5
SUBQUADRATIC = {"xlstm-125m", "zamba2-1.2b"}


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: cm.ModelConfig
    init_params: Callable
    loss_fn: Callable          # (params, batch) -> scalar
    prefill: Callable          # (params, batch) -> logits
    decode_step: Callable      # (params, state, batch) -> (logits, state)
    init_decode_state: Callable
    # the config's SubstratePlan + its default-rule ProductSubstrate,
    # both resolved once at build time
    substrate: Any = None
    plan: Any = None


def _lm_bundle(cfg: cm.ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        init_params=lambda rng: lm.init_params(cfg, rng),
        loss_fn=lambda p, b: lm.loss_fn(cfg, p, b),
        prefill=lambda p, b: lm.prefill(cfg, p, b["tokens"], b.get("patch_embeds")),
        decode_step=lambda p, s, b: lm.decode_step(cfg, p, s, b["token"], b["cache_len"]),
        init_decode_state=lambda batch, max_len: lm.init_kv_caches(cfg, batch, max_len),
    )


def _xlstm_bundle(cfg: cm.ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        init_params=lambda rng: xlstm.init_params(cfg, rng),
        loss_fn=lambda p, b: xlstm.loss_fn(cfg, p, b),
        prefill=lambda p, b: xlstm.prefill(cfg, p, b["tokens"]),
        decode_step=lambda p, s, b: xlstm.decode_step(cfg, p, s, b["token"], b["cache_len"]),
        init_decode_state=lambda batch, max_len: xlstm.init_decode_state(cfg, batch),
    )


def _zamba_bundle(cfg: cm.ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        init_params=lambda rng: zamba.init_params(cfg, rng),
        loss_fn=lambda p, b: zamba.loss_fn(cfg, p, b),
        prefill=lambda p, b: zamba.prefill(cfg, p, b["tokens"]),
        decode_step=lambda p, s, b: zamba.decode_step(cfg, p, s, b["token"], b["cache_len"]),
        init_decode_state=lambda batch, max_len: zamba.init_decode_state(cfg, batch, max_len),
    )


def _encdec_bundle(cfg: cm.ModelConfig) -> ModelBundle:
    def init_state(batch, max_len):
        st = encdec.init_kv_caches(cfg, batch, max_len)
        st["enc_out"] = jnp.zeros((batch, cfg.n_frames, cfg.d_model), cfg.dtype)
        return st

    return ModelBundle(
        cfg=cfg,
        init_params=lambda rng: encdec.init_params(cfg, rng),
        loss_fn=lambda p, b: encdec.loss_fn(cfg, p, b),
        prefill=lambda p, b: encdec.prefill(cfg, p, b["tokens"], b["frames"]),
        decode_step=lambda p, s, b: encdec.decode_step(cfg, p, s, b["token"], b["cache_len"]),
        init_decode_state=init_state,
    )


def _with_substrate(builder: Callable) -> Callable:
    """Wrap a family builder so the config's substrate plan resolves exactly
    once at bundle build (``get_substrate`` is lru-cached, so layers
    re-resolving by spec string hit the same instances). ``bundle.substrate``
    is the plan's *default* substrate — per-site overrides resolve inside
    ``models.common.dense`` via the plan itself (``bundle.plan``)."""

    def build(cfg: cm.ModelConfig) -> ModelBundle:
        bundle = builder(cfg)
        plan = cm.substrate_plan(cfg)
        return dataclasses.replace(
            bundle, substrate=psub.get_substrate(plan.default), plan=plan)

    return build


_BUILDERS = {
    "lm": _with_substrate(_lm_bundle),
    "vlm": _with_substrate(_lm_bundle),
    "xlstm": _with_substrate(_xlstm_bundle),
    "zamba": _with_substrate(_zamba_bundle),
    "encdec": _with_substrate(_encdec_bundle),
}


def build_bundle(cfg: cm.ModelConfig) -> ModelBundle:
    """Build a bundle from an explicit config (registered or reduced)."""
    return _BUILDERS[cfg.family](cfg)

_REGISTRY: Dict[str, cm.ModelConfig] = {}


def register(cfg: cm.ModelConfig) -> cm.ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> cm.ModelConfig:
    _ensure_loaded()
    cfg = _REGISTRY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_bundle(name: str, **overrides) -> ModelBundle:
    cfg = get_config(name, **overrides)
    return _BUILDERS[cfg.family](cfg)


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers all archs)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, dry-run pattern)
# ---------------------------------------------------------------------------


def input_specs(cfg: cm.ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), cfg.dtype)
            batch["tokens"] = sds((b, s), i32)
            batch["labels"] = sds((b, s), i32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.n_frames, cfg.d_model), cfg.dtype)
        return batch
    # decode: one new token against a cache of length seq_len
    return {"token": sds((b, 1), i32),
            "cache_len": jax.ShapeDtypeStruct((), i32)}


def decode_state_specs(bundle: ModelBundle, shape: ShapeSpec):
    """ShapeDtypeStructs of the decode state (KV caches / SSM states)."""
    return jax.eval_shape(
        lambda: bundle.init_decode_state(shape.global_batch, shape.seq_len)
    )


def param_specs(bundle: ModelBundle):
    """ShapeDtypeStructs of the parameter tree — no allocation."""
    return jax.eval_shape(lambda: bundle.init_params(jax.random.PRNGKey(0)))
