"""Shared model config + transformer building blocks.

Pure-functional layers (params = nested dicts of jnp arrays) designed to
lower efficiently at 1T-parameter scale:

* layers applied under ``lax.scan`` over stacked params (compact HLO);
* attention uses online-softmax over KV chunks (no S×S score tensor — a
  32k-token prefill would otherwise materialize petabytes);
* LM loss is chunked over the sequence (big-vocab logits never fully
  materialize);
* MoE uses capacity-based sort-free dispatch (bincount ranks + scatter),
  giving the true T·k/E expert FLOP profile instead of dense all-experts;
* every matmul routes through ``dense()`` which resolves ``cfg.dot_mode``
  through the :mod:`repro.nn.substrate` ProductSubstrate registry — the
  paper's approximate multiplier (and its Pallas TPU kernel,
  ``approx_pallas``) is a first-class execution mode of the whole model zoo.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding as sh
from repro.nn import plan as splan
from repro.nn import substrate as psub

Array = jnp.ndarray
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # lm | encdec | vlm | xlstm | zamba
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 1
    moe_interleave: int = 1        # MoE every k-th layer
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # attention
    qkv_bias: bool = False
    local_window: int = 0          # sliding-window size for local layers
    local_global_ratio: int = 0    # e.g. 5 -> 5 local : 1 global
    rope_theta: float = 1e4
    # SSM / recurrent
    ssm_state: int = 0
    conv_width: int = 4
    shared_attn_every: int = 0     # zamba: shared attention block period
    # modality frontend stubs
    n_frames: int = 0              # whisper encoder frames (post-conv stub)
    n_patches: int = 0             # paligemma image patches
    # encoder (enc-dec only)
    n_encoder_layers: int = 0
    # execution
    dtype: Any = jnp.bfloat16
    dot_mode: str = "exact"        # DEPRECATED single substrate spec
                                   # "backend[:mult_name]"; kept as the
                                   # uniform-plan shim — prefer dot_plan
    dot_plan: Any = None           # site-addressed substrate assignment:
                                   # a repro.nn.plan.SubstratePlan (or a
                                   # spec string / plan dict, normalized by
                                   # substrate_plan()); None → dot_mode
    remat: bool = True
    attn_chunk: int = 512
    loss_chunk: int = 512
    cost_unroll: bool = False   # unroll inner (seq-chunk) scans so XLA
                                # cost_analysis counts every iteration —
                                # used by the roofline cost lowerings only

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_ff_expert(self) -> int:
        return self.d_ff

    def param_count(self) -> int:
        """Total parameter count (used for 6·N·D model FLOPs)."""
        d, v = self.d_model, self.vocab
        attn = d * self.n_heads * self.dh + 2 * d * self.n_kv_heads * self.dh \
            + self.n_heads * self.dh * d
        dense_ffn = 3 * d * self.d_ff
        emb = v * d
        if self.family == "xlstm":
            per_layer = 8 * d * d // 2  # m/sLSTM projections (approx.)
            return self.n_layers * per_layer + 2 * emb
        if self.family == "zamba":
            d_in = 2 * d
            mamba = d * (2 * d_in + 2 * self.ssm_state + 32) + d_in * d
            n_attn = self.n_layers // max(1, self.shared_attn_every)
            return self.n_layers * mamba + (attn + dense_ffn) + emb
        n_moe = self.n_layers // self.moe_interleave if self.n_experts else 0
        n_dense = self.n_layers - n_moe
        moe_ffn = n_moe * (self.n_experts * 3 * d * self.d_ff_expert
                           + d * self.n_experts
                           + (3 * d * self.d_ff_expert if self.shared_expert else 0))
        total = self.n_layers * attn + n_dense * dense_ffn + moe_ffn + emb
        if self.family == "encdec":
            total += self.n_encoder_layers * (attn + dense_ffn + attn)  # + cross-attn
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared instead of all)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        n_moe = self.n_layers // self.moe_interleave
        all_experts = n_moe * self.n_experts * 3 * d * self.d_ff_expert
        active = n_moe * (self.top_k + (1 if self.shared_expert else 0)) \
            * 3 * d * self.d_ff_expert
        return self.param_count() - all_experts + active


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------


#: dense()'s quantization boundary: the historical `dot` policy (per-tensor
#: dynamic activation scale, per-output-channel weight scales).
_DENSE_QUANT = psub.QuantPolicy()


def substrate_plan(cfg: ModelConfig) -> "splan.SubstratePlan":
    """The plan governing this trace: ambient override, else the config's.

    An active :func:`repro.nn.plan.plan_override_scope` wins outright — it
    is how a layer above an already-built model function (the train loop
    resuming under a checkpoint's recorded plan) changes the numerics of
    the whole trace. Otherwise ``cfg.dot_plan`` wins when set (a plan, spec
    string, or plan dict — normalized through
    :func:`repro.nn.plan.as_plan`); otherwise the legacy ``cfg.dot_mode``
    spec auto-wraps into a uniform single-rule plan. The legacy path emits
    a DeprecationWarning for non-default specs — set
    ``dot_plan=SubstratePlan.uniform(spec)`` (or just ``dot_plan=spec``)
    instead.
    """
    override = splan.current_plan_override()
    if override is not None:
        return override
    if cfg.dot_plan is not None:
        return splan.as_plan(cfg.dot_plan)
    if cfg.dot_mode != "exact":
        warnings.warn(
            "cfg.dot_mode is deprecated; set cfg.dot_plan to a "
            "repro.nn.plan.SubstratePlan (a spec string still means a "
            "uniform plan)", DeprecationWarning, stacklevel=3)
    return splan.SubstratePlan.uniform(cfg.dot_mode)


def dense(cfg: ModelConfig, x: Array, w: Array, b: Optional[Array] = None,
          *, site: Optional[str] = None) -> Array:
    """Matmul under the configured product substrate (the paper's technique).

    The substrate is chosen by the config's :func:`substrate_plan` at the
    ambient contraction site (``site`` is the leaf segment under the
    enclosing :func:`repro.nn.plan.site_scope` stack — e.g. ``"wq"`` under
    ``layer.3.attn`` resolves at ``layer.3.attn.wq``). Resolution is
    lru-cached per (plan, site), so per-call overhead is negligible.

    Under a :func:`repro.nn.plan.scan_site_scope` (stacked layers traced
    once under ``lax.scan``), the per-repeat assignments are resolved at
    trace time: when every repeat agrees — the common case — the call
    stays a single static ``dot_general``; otherwise the distinct
    substrates become ``jax.lax.switch`` branches selected by the carried
    layer index, so mixed per-layer plans survive stacked params.

    The contraction runs through ``dot_general`` with the default
    quantization policy; when a
    :func:`repro.nn.substrate.partitioning_scope` is active (the launch
    layer's ``--dot-partition`` mesh path), the contraction lowers through
    shard_map instead of relying on GSPMD to shard the scalar-emulation HLO.
    """
    plan = substrate_plan(cfg)
    part = psub.current_partitioning()
    override = psub.current_dot_override()
    d = splan.dispatch(plan, site)
    if d.index is None:
        spec_str, label = d.groups[0]
        cspec = psub.ContractionSpec.matmul(
            quant=_DENSE_QUANT, partitioning=part, site=label)
        if override is not None:
            out = override(spec_str, x, w, cspec)
        else:
            out = psub.get_substrate(spec_str).dot_general(x, w, cspec)
    else:
        branches = []
        for spec_str, label in d.groups:
            cspec = psub.ContractionSpec.matmul(
                quant=_DENSE_QUANT, partitioning=part, site=label)

            if override is not None:
                def branch(xx, ww, _spec=spec_str, _cs=cspec, _ov=override):
                    return _ov(_spec, xx, ww, _cs)
            else:
                def branch(xx, ww, _s=psub.get_substrate(spec_str), _cs=cspec):
                    return _s.dot_general(xx, ww, _cs)

            branches.append(branch)
        sel = jnp.asarray(np.asarray(d.branch_of, np.int32))[d.index]
        out = jax.lax.switch(sel, branches, x, w)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) / math.sqrt(d_in)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if dh % 2:
        rot = jnp.concatenate([rot, x[..., -1:]], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, online softmax over KV chunks, causal/local windows)
# ---------------------------------------------------------------------------


def attention_chunked(q: Array, k: Array, v: Array, *, q_offset: Array,
                      causal: bool = True, window: int = 0,
                      chunk: int = 512, unroll: bool = False) -> Array:
    """Online-softmax attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh); q_offset: scalar — the
    absolute position of q[0] (Sq == Skv and offset 0 during training;
    decode passes Sq=1, offset=cache_len). window > 0 = sliding-window
    (local) attention. Never materializes an (Sq, Skv) score tensor larger
    than (Sq, chunk).
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, skv)
    n_chunks = skv // chunk
    rem = skv - n_chunks * chunk

    q_pos = q_offset + jnp.arange(sq)

    def score_block(k_blk, v_blk, kv_start):
        # k_blk: (B, C, Hkv, dh) -> scores (B, Sq, Hkv, G, C)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        kv_pos = kv_start + jnp.arange(k_blk.shape[1])
        mask = jnp.ones((sq, k_blk.shape[1]), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window > 0:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        return s, v_blk

    def combine(carry, blk):
        m_prev, l_prev, acc = carry
        s, v_blk = blk
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * jnp.exp(m_prev - m_new) + p.sum(-1)
        acc = acc * jnp.exp(m_prev - m_new)[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc)

    m0 = jnp.full((b, sq, hkv, group), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, group), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, group, dh), jnp.float32)
    carry = (m0, l0, a0)

    if n_chunks:
        kc = k[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, hkv, dh)
        vc = v[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, hkv, dh)

        def body(c, xs):
            k_blk, v_blk, idx = xs
            return combine(c, score_block(k_blk, v_blk, idx * chunk)), None

        # nested remat: recompute per-chunk scores in the backward pass
        # instead of saving (Sq × chunk) residuals per step
        body = jax.checkpoint(body)
        carry, _ = jax.lax.scan(
            body, carry,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(n_chunks)),
            unroll=n_chunks if unroll else 1,
        )
    if rem:
        carry = combine(carry, score_block(k[:, n_chunks * chunk:],
                                           v[:, n_chunks * chunk:],
                                           n_chunks * chunk))
    _, l, acc = carry
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def init_attn(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    return {
        "wq": init_dense(ks[0], d, h * dh, cfg.dtype, cfg.qkv_bias),
        "wk": init_dense(ks[1], d, hkv * dh, cfg.dtype, cfg.qkv_bias),
        "wv": init_dense(ks[2], d, hkv * dh, cfg.dtype, cfg.qkv_bias),
        "wo": init_dense(ks[3], h * dh, d, cfg.dtype),
        "ln": jnp.ones((d,), jnp.float32),
    }


def attn_block(cfg: ModelConfig, p: Params, x: Array, *, positions: Array,
               window: int = 0, kv_cache: Optional[Tuple[Array, Array]] = None,
               cache_len: Optional[Array] = None, cross_kv=None,
               causal: bool = True,
               ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Pre-norm GQA attention block. Returns (residual output, new kv).

    kv_cache: (K, V) of shape (B, S_max, Hkv, dh) for decode; cache_len is
    the current length (new token written at that index).
    cross_kv: precomputed (K, V) for encoder-decoder cross attention.
    """
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    xn = rms_norm(x, p["ln"])
    with splan.site_scope("attn"):
        q = dense(cfg, xn, p["wq"]["w"], p["wq"].get("b"),
                  site="wq").reshape(b, s, h, dh)
        if cross_kv is None:
            k = dense(cfg, xn, p["wk"]["w"], p["wk"].get("b"),
                      site="wk").reshape(b, s, hkv, dh)
            v = dense(cfg, xn, p["wv"]["w"], p["wv"].get("b"),
                      site="wv").reshape(b, s, hkv, dh)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        else:
            k, v = cross_kv

    q = sh.constrain(q, "batch", "seq", "heads", "head_dim")

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_len, axis=1)
        new_cache = (ck, cv)
        k, v = ck, cv
        q_offset = cache_len
    else:
        q_offset = jnp.array(0, jnp.int32) if cross_kv is None else None
        causal = causal and cross_kv is None

    out = attention_chunked(
        q, k, v,
        q_offset=(q_offset if q_offset is not None else jnp.array(0, jnp.int32)),
        causal=causal, window=window, chunk=cfg.attn_chunk,
        unroll=cfg.cost_unroll,
    )
    with splan.site_scope("attn"):
        out = dense(cfg, out.reshape(b, s, h * dh), p["wo"]["w"], site="wo")
    return x + out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Dense (SwiGLU) FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": init_dense(ks[0], d, f, cfg.dtype),
        "wg": init_dense(ks[1], d, f, cfg.dtype),
        "wo": init_dense(ks[2], f, d, cfg.dtype),
        "ln": jnp.ones((d,), jnp.float32),
    }


def ffn_block(cfg: ModelConfig, p: Params, x: Array) -> Array:
    xn = rms_norm(x, p["ln"])
    with splan.site_scope("ffn"):
        hidden = (jax.nn.silu(dense(cfg, xn, p["wg"]["w"], site="wg"))
                  * dense(cfg, xn, p["wi"]["w"], site="wi"))
        hidden = sh.constrain(hidden, "batch", "seq", "mlp")
        return x + dense(cfg, hidden, p["wo"]["w"], site="wo").astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity dispatch; expert-parallel over "model" axis)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    std = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * std),
        "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * std).astype(cfg.dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * std).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f)).astype(cfg.dtype),
        "ln": jnp.ones((d,), jnp.float32),
    }
    if cfg.shared_expert:
        p["shared"] = init_ffn(ks[4], cfg, cfg.d_ff_expert)
    return p


def moe_block(cfg: ModelConfig, p: Params, x: Array) -> Array:
    """Top-k capacity-based MoE (token-dropping on overflow).

    Under a mesh with a "model" axis, dispatch runs EXPERT-PARALLEL via
    shard_map: every data shard routes its own tokens locally (local
    scatter into an (E, C_local, d) buffer), an all-to-all over the model
    axis moves token slots to their expert owners, experts run as batched
    matmuls on the local expert shard, and a reverse all-to-all brings
    results home — the production EP pattern with *explicit* collectives
    (GSPMD replicates computed-index scatters otherwise; measured: 748 GB →
    few-GB temp on kimi-k2). Without a mesh (smoke tests / tiny batches)
    the same dispatch runs as plain local ops.
    """
    mesh = sh.current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        t = x.shape[0] * x.shape[1]
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_shards = mesh.shape["model"]
        for a in dp:
            n_shards *= mesh.shape[a]
        if (t % n_shards == 0 and cfg.n_experts % mesh.shape["model"] == 0):
            return _moe_block_ep(cfg, p, x, mesh, dp)
    return _moe_block_local(cfg, p, x)


def _dispatch_local(cfg: ModelConfig, xn: Array, router: Array):
    """Route tokens: returns (buf (E, C, d), combine info). Pure-local ops.

    Ranking within each expert is SORT-based: O(T·logT) compares instead of
    the textbook O(T·E) one-hot cumsum — at kimi-k2 scale (T·k = 0.5 M rows
    per shard, E = 384) the cumsum's (T·k, E) int tensor dominated the whole
    step's memory traffic (measured: ~40 % of t_memory; see EXPERIMENTS.md
    §Perf iteration 1).
    """
    t, d = xn.shape
    e, k = cfg.n_experts, cfg.top_k
    gates = jax.nn.softmax(jnp.dot(xn.astype(jnp.float32), router), axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                       # (t, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    cap = int(max(1, math.ceil(t * k * cfg.capacity_factor / e)))
    flat_e = topi.reshape(-1)                                  # (t*k,)
    order = jnp.argsort(flat_e, stable=True)                   # token-order ties
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e))          # group starts
    rank_sorted = jnp.arange(t * k) - start[sorted_e]
    my_rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = my_rank < cap
    slot = jnp.where(keep, flat_e * cap + my_rank, e * cap)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), xn.dtype).at[slot].set(xn[tok_idx])
    return buf[:e * cap].reshape(e, cap, d), (slot, topw, keep, cap)


def _combine_local(out: Array, info, t: int):
    """Inverse of _dispatch_local: weighted gather back to token order."""
    slot, topw, keep, cap = info
    e = out.shape[0]
    d = out.shape[-1]
    out_flat = jnp.concatenate([out.reshape(e * cap, d),
                                jnp.zeros((1, d), out.dtype)], axis=0)
    gathered = out_flat[slot]                                  # (t*k, d)
    w = (topw.reshape(-1) * keep).astype(gathered.dtype)
    k = topw.shape[1]
    return (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)


def _expert_ffn(p: Params, buf: Array) -> Array:
    hid = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(buf.dtype)))
    hid = hid * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", hid, p["wo"].astype(hid.dtype))


def _moe_block_local(cfg: ModelConfig, p: Params, x: Array) -> Array:
    b, s, d = x.shape
    t = b * s
    xn = rms_norm(x, p["ln"]).reshape(t, d)
    buf, info = _dispatch_local(cfg, xn, p["router"])
    out = _expert_ffn(p, buf)
    y = _combine_local(out, info, t)
    if cfg.shared_expert:
        with splan.site_scope("moe", "shared"):
            y = y + (ffn_block(cfg, p["shared"], xn.reshape(b, s, d))
                     - xn.reshape(b, s, d)).reshape(t, d)
    return x + y.reshape(b, s, d).astype(x.dtype)


def _moe_block_ep(cfg: ModelConfig, p: Params, x: Array, mesh, dp) -> Array:
    """Expert-parallel MoE: shard_map(local dispatch → a2a → FFN → a2a).

    Every device must route a DISTINCT token slice (replicating tokens over
    "model" computes every dispatch M× redundantly — measured as an 18×
    useful-flops gap, §Perf iteration 2), but exposing a dp×model token
    sharding at the shard_map boundary makes GSPMD fall back to full
    rematerialization when resharding the remat residuals (measured:
    2.8 TiB/layer of all-gathers, §Perf iteration 3). So the boundary stays
    dp-sharded and each model shard SLICES its 1/M share inside the body —
    the reshard becomes an explicit slice + all-gather pair that transposes
    cleanly in the backward pass.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    t = b * s
    xn = rms_norm(x, p["ln"]).reshape(t, d)
    m_size = mesh.shape["model"]

    def body(xn_l, router, wi_l, wg_l, wo_l):
        # xn_l: (t_dp, d) — replicated over "model"; take this shard's share
        t_mm = xn_l.shape[0] // m_size
        m_idx = jax.lax.axis_index("model")
        xn_mine = jax.lax.dynamic_slice_in_dim(xn_l, m_idx * t_mm, t_mm, 0)
        buf, info = _dispatch_local(cfg, xn_mine, router)       # (E, C_l, d)
        # all-to-all: split expert dim across "model", gather capacity dim
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)                    # (E_l, C_l*M, d)
        out = _expert_ffn({"wi": wi_l, "wg": wg_l, "wo": wo_l}, buf)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)                    # (E, C_l, d)
        y_mine = _combine_local(out, info, t_mm)                # (t_mm, d)
        return jax.lax.all_gather(y_mine, "model", axis=0, tiled=True)

    dp_spec = dp if len(dp) > 1 else dp[0]
    y = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(dp_spec, None),
        check_rep=False,
    )(xn, p["router"], p["wi"], p["wg"], p["wo"])

    if cfg.shared_expert:
        with splan.site_scope("moe", "shared"):
            y = y + (ffn_block(cfg, p["shared"], xn.reshape(b, s, d))
                     - xn.reshape(b, s, d)).reshape(t, d)
    return x + y.reshape(b, s, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head / chunked loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> Params:
    emb = jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
    return {"emb": (emb / math.sqrt(cfg.d_model)).astype(cfg.dtype),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32)}


def embed(cfg: ModelConfig, p: Params, tokens: Array) -> Array:
    e = sh.constrain(p["emb"], "vocab", "embed")
    x = e[tokens]
    return sh.constrain(x, "batch", "seq", "embed")


def lm_loss_chunked(cfg: ModelConfig, p: Params, x: Array, labels: Array) -> Array:
    """Streaming softmax-xent: never materializes (B, S, V) at once."""
    b, s, d = x.shape
    x = rms_norm(x, p["ln_f"])
    chunk = min(cfg.loss_chunk, s)
    n = s // chunk
    emb_t = p["emb"].astype(jnp.float32).T  # (d, V)

    def body(acc, idx):
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", xs.astype(jnp.float32), emb_t)
        logits = sh.constrain(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return acc + (logz - gold).sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            jnp.arange(n),
                            unroll=n if cfg.cost_unroll else 1)
    rem = s - n * chunk
    if rem:
        logits = jnp.einsum("bsd,dv->bsv", x[:, n * chunk:].astype(jnp.float32), emb_t)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, n * chunk:][..., None], -1)[..., 0]
        total = total + (logz - gold).sum()
    return total / (b * s)


def lm_logits(cfg: ModelConfig, p: Params, x: Array) -> Array:
    x = rms_norm(x, p["ln_f"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        p["emb"].astype(jnp.float32))
    return sh.constrain(logits, "batch", "seq", "vocab")
