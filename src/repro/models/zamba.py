"""Zamba2 hybrid (arXiv:2411.15242): Mamba2 backbone + shared attention block.

Mamba2 runs the SSD chunked algorithm: intra-chunk quadratic form +
inter-chunk diagonal state recurrence (state (B, H, dh, d_state) carried
by a lax.scan over chunks). The shared attention block (full transformer
block, one set of weights) is applied every ``shared_attn_every`` layers,
reusing the same parameters each time — Zamba's signature trick.

Sub-quadratic with O(1) decode state → serves ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.nn import plan as splan

Array = jnp.ndarray
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def _d_inner(cfg) -> int:
    return 2 * cfg.d_model


def init_mamba(key, cfg: cm.ModelConfig) -> Params:
    d = cfg.d_model
    di = _d_inner(cfg)
    h = cfg.n_heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "in_proj": cm.init_dense(ks[0], d, 2 * di + 2 * n + h, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(cfg.dtype),
        "a_log": jnp.zeros((h,), jnp.float32),            # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_proj": cm.init_dense(ks[2], di, d, cfg.dtype),
    }


def _causal_conv1d(x: Array, w: Array, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (W,C); state: (B,W-1,C)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return out, new_state


def mamba_scan(xh, dt, B, C, a, state, chunk: int, unroll: bool = False):
    """SSD chunked recurrence.

    xh: (B,S,H,dh); dt: (B,S,H) >0; B,C: (B,S,n); a: (H,) negative;
    state: (B,H,dh,n). y_t = C_t·h_t + D-skip handled outside.
    """
    b, s, h, dh = xh.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    nc = s // chunk
    assert nc * chunk == s

    xc = xh.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    def body(st, xs):
        xx, ddt, bb, cc = xs                       # (B,C,H,dh),(B,C,H),(B,C,n)
        la = ddt * a[None, None, :]                # log decay per step (<0)
        lcum = jnp.cumsum(la, axis=1)              # (B,C,H)
        # intra-chunk: y_t = sum_{u<=t} exp(lcum_t - lcum_u) dt_u (C_t·B_u) x_u
        scores = jnp.einsum("btn,bun->btu", cc, bb)              # (B,C,C)
        decay = jnp.exp(lcum[:, :, None, :] - lcum[:, None, :, :])  # (B,t,u,H)
        mask = jnp.tril(jnp.ones((xx.shape[1], xx.shape[1]), bool))
        w = jnp.where(mask[None, :, :, None], scores[..., None] * decay, 0.0)
        y_intra = jnp.einsum("btuh,buh,buhd->bthd", w, ddt, xx)
        # inter-chunk: y_t += exp(lcum_t) C_t · st
        y_inter = jnp.einsum("bth,btn,bhdn->bthd", jnp.exp(lcum), cc, st)
        # state update
        decay_all = jnp.exp(lcum[:, -1, :])        # (B,H)
        wtail = jnp.exp(lcum[:, -1:, :] - lcum) * ddt           # (B,C,H)
        st_new = st * decay_all[:, :, None, None] + jnp.einsum(
            "buh,buhd,bun->bhdn", wtail, xx, bb)
        return st_new, y_intra + y_inter

    state, ys = jax.lax.scan(jax.checkpoint(body), state,
                             (xc.astype(jnp.float32), dtc, Bc.astype(jnp.float32),
                              Cc.astype(jnp.float32)),
                             unroll=nc if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return y, state


def mamba_block(cfg: cm.ModelConfig, p: Params, x: Array, state=None,
                conv_state=None) -> Tuple[Array, Tuple]:
    b, s, d = x.shape
    di, h, n = _d_inner(cfg), cfg.n_heads, cfg.ssm_state
    dh = di // h
    xn = cm.rms_norm(x, p["ln"])
    proj = cm.dense(cfg, xn, p["in_proj"]["w"], site="in_proj")
    xin, z, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    xin, new_conv = _causal_conv1d(xin, p["conv_w"], conv_state)
    xin = jax.nn.silu(xin)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    if state is None:
        state = jnp.zeros((b, h, dh, n), jnp.float32)
    xh = xin.reshape(b, s, h, dh)
    y, new_state = mamba_scan(xh, dt, Bm, Cm, a, state,
                              chunk=min(cfg.attn_chunk, s),
                              unroll=cfg.cost_unroll)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = (y.reshape(b, s, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (x + cm.dense(cfg, y, p["out_proj"]["w"],
                         site="out_proj").astype(x.dtype),
            (new_state, new_conv))


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------


def init_params(cfg: cm.ModelConfig, rng: Array) -> Params:
    keys = jax.random.split(rng, cfg.n_layers + 3)
    layers = [init_mamba(keys[i], cfg) for i in range(cfg.n_layers)]
    shared = {"attn": cm.init_attn(keys[-3], cfg), "ffn": cm.init_ffn(keys[-2], cfg)}
    return {"embed": cm.init_embed(keys[-1], cfg), "mamba": layers, "shared": shared}


def _shared_positions(cfg) -> list:
    k = cfg.shared_attn_every
    return [i for i in range(cfg.n_layers) if k and i % k == k - 1]


def forward(cfg: cm.ModelConfig, params: Params, tokens: Array) -> Array:
    x = cm.embed(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    shared_at = set(_shared_positions(cfg))
    for i, p in enumerate(params["mamba"]):
        def fn(xx, pp=p, scope=(f"layer.{i}", "mamba")):
            with splan.site_scope(*scope):
                return mamba_block(cfg, pp, xx)[0]
        x = jax.checkpoint(fn)(x) if cfg.remat else fn(x)
        if i in shared_at:
            def shared_fn(xx):
                with splan.site_scope("shared"):
                    y, _ = cm.attn_block(cfg, params["shared"]["attn"], xx,
                                         positions=positions)
                    return cm.ffn_block(cfg, params["shared"]["ffn"], y)
            x = jax.checkpoint(shared_fn)(x) if cfg.remat else shared_fn(x)
    return x


def loss_fn(cfg: cm.ModelConfig, params: Params, batch: Dict[str, Array]) -> Array:
    x = forward(cfg, params, batch["tokens"])
    return cm.lm_loss_chunked(cfg, params["embed"], x, batch["labels"])


def init_decode_state(cfg: cm.ModelConfig, batch: int, max_len: int):
    di, h, n = _d_inner(cfg), cfg.n_heads, cfg.ssm_state
    dh = di // h
    states = {
        "mamba": [
            (jnp.zeros((batch, h, dh, n), jnp.float32),
             jnp.zeros((batch, cfg.conv_width - 1, di), cfg.dtype))
            for _ in range(cfg.n_layers)
        ],
        "shared_kv": [
            (jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
             jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype))
            for _ in _shared_positions(cfg)
        ],
    }
    return states


def decode_step(cfg: cm.ModelConfig, params: Params, states, token: Array,
                cache_len: Array):
    """One decode step: O(1) mamba state + shared-attn KV lookups."""
    x = cm.embed(cfg, params["embed"], token)
    b = x.shape[0]
    positions = jnp.broadcast_to(cache_len, (b, 1)).astype(jnp.int32)
    shared_at = _shared_positions(cfg)
    new_mamba, new_kv = [], []
    kv_i = 0
    for i, p in enumerate(params["mamba"]):
        st, conv_st = states["mamba"][i]
        with splan.site_scope(f"layer.{i}", "mamba"):
            x, (nst, ncv) = mamba_block(cfg, p, x, state=st,
                                        conv_state=conv_st)
        new_mamba.append((nst, ncv))
        if i in shared_at:
            with splan.site_scope("shared"):
                x, nkv = cm.attn_block(cfg, params["shared"]["attn"], x,
                                       positions=positions,
                                       kv_cache=states["shared_kv"][kv_i],
                                       cache_len=cache_len)
                x = cm.ffn_block(cfg, params["shared"]["ffn"], x)
            new_kv.append(nkv)
            kv_i += 1
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits, {"mamba": new_mamba, "shared_kv": new_kv}


def prefill(cfg: cm.ModelConfig, params: Params, tokens: Array) -> Array:
    x = forward(cfg, params, tokens)
    return cm.lm_logits(cfg, params["embed"], x[:, -1:, :])
