"""Deterministic synthetic LM data pipeline.

Produces a reproducible, seekable token stream (Zipf-ish unigram mixture +
Markov bigram structure so the LM loss actually decreases), sharded by host
and prefetched on a background thread. ``seek(step)`` gives exact resume
after restart — the fault-tolerance contract the train loop relies on.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMStream:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1, prefetch: int = 2):
        assert batch % n_hosts == 0, "global batch must divide across hosts"
        self.vocab = vocab
        self.batch = batch // n_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.step = 0
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # bigram structure: next ~ 0.7 * (prev * a + c) mod V, else unigram
        rng = np.random.default_rng(seed)
        self._a = int(rng.integers(3, 97)) * 2 + 1
        self._c = int(rng.integers(1, vocab))
        zipf = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self._unigram = zipf / zipf.sum()

    def seek(self, step: int):
        self.step = step

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.host_id)
        b, s, v = self.batch, self.seq_len, self.vocab
        first = rng.choice(v, size=(b, 1), p=self._unigram)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, :1] = first
        noise = rng.random((b, s))
        fresh = rng.choice(v, size=(b, s), p=self._unigram)
        for t in range(s):
            structured = (toks[:, t] * self._a + self._c) % v
            toks[:, t + 1] = np.where(noise[:, t] < 0.7, structured, fresh[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    # -- synchronous API ----------------------------------------------------

    def next(self) -> Dict[str, np.ndarray]:
        batch = self._batch_at(self.step)
        self.step += 1
        return batch

    # -- prefetching iterator -------------------------------------------------

    def start_prefetch(self, depth: int = 2):
        self._queue = queue.Queue(maxsize=depth)
        self._stop.clear()

        def work():
            step = self.step
            while not self._stop.is_set():
                item = (step, self._batch_at(step))
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def next_prefetched(self) -> Dict[str, np.ndarray]:
        assert self._queue is not None, "call start_prefetch() first"
        step, batch = self._queue.get()
        self.step = step + 1
        return batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
