"""Data pipelines: synthetic LM stream + procedural images."""
from repro.data.images import (  # noqa: F401
    image_batch, mixed_shape_batch, photo_like, test_image)
from repro.data.synthetic import SyntheticLMStream  # noqa: F401
