"""Data pipelines: synthetic LM stream + procedural images."""
from repro.data.images import image_batch, photo_like, test_image  # noqa: F401
from repro.data.synthetic import SyntheticLMStream  # noqa: F401
