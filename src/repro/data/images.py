"""Procedural test images for the edge-detection application (no network,
no binary assets — images are generated, deterministic, and license-free)."""
from __future__ import annotations

import numpy as np


def test_image(h: int = 96, w: int = 96) -> np.ndarray:
    """Geometric test card: gradient + rectangle + disk (strong edges)."""
    yy, xx = np.mgrid[0:h, 0:w]
    img = (xx * 255 / w).astype(np.float64)
    img[h // 4:h // 2, w // 4:w // 2] = 220
    img[(yy - 3 * h // 4) ** 2 + (xx - 3 * w // 4) ** 2 < (h // 6) ** 2] = 30
    return img.astype(np.uint8)


def image_batch(n: int = 8, h: int = 64, w: int = 64, seed: int = 0,
                noise: float = 0.0) -> np.ndarray:
    """(n, h, w) uint8 batch of distinct procedural images.

    Alternates shifted geometric test cards with photo-statistics images so a
    batch exercises both hard edges and natural gradients — the batched
    edge-detection pipeline (``nn.conv.edge_detect_batched``) consumes this.
    ``noise`` adds i.i.d. Gaussian sensor noise of that std (in pixel units)
    to every image, for robustness sweeps of the approximate edge maps.
    """
    base = test_image(h, w)
    out = np.empty((n, h, w), np.uint8)
    for i in range(n):
        if i % 2 == 0:
            out[i] = np.roll(base, (i * 3) % w, axis=1)
        else:
            out[i] = photo_like(h, w, seed=seed + i)
    if noise > 0:
        out = _add_noise(out, noise, seed)
    return out


def _add_noise(imgs: np.ndarray, std: float, seed: int) -> np.ndarray:
    """Gaussian sensor noise of ``std`` pixel units, clipped back to uint8."""
    r = np.random.default_rng(seed + 0x5EED)
    noisy = imgs.astype(np.float64) + r.normal(0, std, imgs.shape)
    return np.clip(noisy, 0, 255).astype(np.uint8)


MIXED_SHAPES = ((48, 64), (64, 64), (33, 47), (64, 96), (96, 96), (17, 129))


def mixed_shape_batch(n: int = 8, shapes=MIXED_SHAPES, seed: int = 0,
                      noise: float = 0.0) -> list:
    """List of n uint8 images cycling through heterogeneous (h, w) shapes.

    The ragged counterpart of :func:`image_batch` — same alternation of
    shifted test cards and photo-statistics images, but cycling shapes that
    include non-multiples of common bucket granularities, so shape-bucketing
    and padding paths (``serving.EdgeDetectService``) are exercised by a real
    generator instead of hand-built arrays.
    """
    if not shapes:
        raise ValueError("shapes must be non-empty")
    out = []
    for i in range(n):
        h, w = shapes[i % len(shapes)]
        if i % 2 == 0:
            img = np.roll(test_image(h, w), (seed + 3 * i) % w, axis=1)
        else:
            img = photo_like(h, w, seed=seed + i)
        out.append(_add_noise(img, noise, seed + i) if noise > 0 else img)
    return out


def photo_like(h: int = 128, w: int = 128, seed: int = 3) -> np.ndarray:
    """Natural-statistics image: low-frequency background + objects + texture."""
    r = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    img = np.zeros((h, w))
    for _ in range(6):
        fy, fx = r.uniform(0.5, 3, 2)
        ph = r.uniform(0, 2 * np.pi, 2)
        img += r.uniform(20, 60) * np.cos(2 * np.pi * fy * yy / h + ph[0]) \
            * np.cos(2 * np.pi * fx * xx / w + ph[1])
    img += 128
    img[h // 5:h // 2, w // 6:w // 3] += 60
    img[(yy - 2 * h // 3) ** 2 + (xx - 2 * w // 3) ** 2 < (h // 5) ** 2] -= 70
    img += r.normal(0, 6, (h, w))
    return np.clip(img, 0, 255).astype(np.uint8)
