"""Quantized / approximate neural-network layers.

``repro.nn.substrate`` holds the ProductSubstrate registry — the single
dispatch point for every scalar-product execution mode (exact, int8,
approx_bitexact, approx_lut, approx_stat, approx_pallas).
``repro.nn.plan`` maps contraction *sites* to substrate specs
(:class:`~repro.nn.plan.SubstratePlan`) — per-layer mixed-substrate
assignments over the same registry.
"""
from repro.nn import approx_dot, conv, plan, quant, substrate  # noqa: F401
from repro.nn.plan import SubstratePlan, as_plan  # noqa: F401
from repro.nn.substrate import (  # noqa: F401
    ContractionSpec,
    Partitioning,
    ProductSubstrate,
    QuantPolicy,
    SubstrateMeta,
    get_substrate,
    list_substrates,
)
