"""Quantized / approximate neural-network layers."""
from repro.nn import approx_dot, conv, quant  # noqa: F401
