"""2-D convolution under the approximate multiplier (paper §4).

The paper's application: 3×3 Laplacian edge detection where every
pixel×coefficient product runs through the proposed approximate signed
multiplier, followed by exact accumulation (the MAC's adder tree is exact).

Pixels are mapped to the signed 8-bit operand domain by an arithmetic right
shift (0..255 → 0..127), matching the fixed-point convention of
approximate-multiplier papers; kernel coefficients are signed 8-bit already.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multiplier as mult

Array = jnp.ndarray

LAPLACIAN = np.array([[-1, -1, -1], [-1, 8, -1], [-1, -1, -1]], dtype=np.int32)


def to_signed_pixels(img: Array) -> Array:
    """uint8 image (0..255) → signed operand domain (0..127)."""
    return (jnp.asarray(img, jnp.int32) >> 1).astype(jnp.int32)


def conv2d_int(img: Array, kernel: Array,
               product_fn: Callable[[Array, Array], Array]) -> Array:
    """Zero-padded 'same' 2-D convolution with a custom scalar product.

    img: (H, W) int32 in [-128, 127]; kernel: (kh, kw) int32 in [-128, 127].
    Accumulation is exact int32 (the MAC adder is exact in the paper).
    """
    kh, kw = kernel.shape
    ph, pw = kh // 2, kw // 2
    x = jnp.pad(jnp.asarray(img, jnp.int32), ((ph, ph), (pw, pw)))
    h, w = img.shape
    out = jnp.zeros((h, w), jnp.int32)
    for di in range(kh):
        for dj in range(kw):
            coeff = kernel[di, dj]
            patch = jax.lax.dynamic_slice(x, (di, dj), (h, w))
            out = out + product_fn(patch, jnp.full((), int(coeff), jnp.int32))
    return out


def edge_detect(img_u8: Array, mult_name: str = "proposed") -> Array:
    """Laplacian edge map with the named multiplier; returns uint8 map."""
    fn = mult.ALL_MULTIPLIERS[mult_name]
    px = to_signed_pixels(img_u8)
    raw = conv2d_int(px, jnp.asarray(LAPLACIAN), fn)
    return jnp.clip(raw, 0, 255).astype(jnp.uint8)


def psnr(ref: Array, test: Array, peak: float = 255.0) -> float:
    """PSNR in dB between two uint8 images (paper Fig. 9 metric)."""
    r = jnp.asarray(ref, jnp.float64)
    t = jnp.asarray(test, jnp.float64)
    mse = jnp.mean((r - t) ** 2)
    return float(jnp.where(mse == 0, jnp.inf, 10.0 * jnp.log10(peak**2 / mse)))


def conv2d_float(x: Array, kernel: Array) -> Array:
    """Float reference conv ('same', zero pad) used by NN-layer tests."""
    kh, kw = kernel.shape
    xp = jnp.pad(x, ((kh // 2, kh // 2), (kw // 2, kw // 2)))
    out = jnp.zeros_like(x)
    for di in range(kh):
        for dj in range(kw):
            out = out + kernel[di, dj] * jax.lax.dynamic_slice(xp, (di, dj), x.shape)
    return out
