"""2-D convolution under the approximate multiplier (paper §4).

The paper's application: 3×3 Laplacian edge detection where every
pixel×coefficient product runs through the proposed approximate signed
multiplier, followed by exact accumulation (the MAC's adder tree is exact).

Two execution paths:

* :func:`conv2d_int` — the reference single-image Python double-loop over
  kernel taps, taking an arbitrary scalar-product function (kept as the
  parity oracle for the batched path);
* :func:`conv2d_batched` — batched NHW(C) 'same' convolution lowered to a
  single im2col + substrate contraction, so every registered
  :class:`~repro.nn.substrate.ProductSubstrate` (including the Pallas
  kernel) runs edge detection under one parity contract.

Pixels are mapped to the signed operand domain of the substrate's width by
an arithmetic shift (0..255 → 0..2^(N-1)-1; ``>> 1`` at the default N=8),
matching the fixed-point convention of approximate-multiplier papers;
kernel coefficients must fit the signed N-bit operand range (coefficients
outside it wrap, per the multipliers' two's-complement operand contract —
the Laplacian's center tap 8 wraps to −8 at N=4). Edge maps are rescaled
back to the 8-bit output range before clipping, so PSNR is comparable
across widths.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multiplier as mult

Array = jnp.ndarray

LAPLACIAN = np.array([[-1, -1, -1], [-1, 8, -1], [-1, -1, -1]], dtype=np.int32)


def to_signed_pixels(img: Array, n: int = 8) -> Array:
    """uint8 image(s) (0..255) → signed n-bit operand domain (0..2^(n-1)-1)."""
    x = jnp.asarray(img, jnp.int32)
    return (x >> (9 - n)) if n <= 9 else (x << (n - 9))


def _rescale_raw(raw: Array, n: int) -> Array:
    """Map a width-n conv response back to the 8-bit output range.

    Pixels scale as 2^(n-8) relative to the n=8 harness, so the response
    rescales by 2^(8-n); identity at the default width.
    """
    if n == 8:
        return raw
    return (raw << (8 - n)) if n < 8 else (raw >> (n - 8))


def conv2d_int(img: Array, kernel: Array,
               product_fn: Callable[[Array, Array], Array]) -> Array:
    """Zero-padded 'same' 2-D convolution with a custom scalar product.

    img: (H, W) int32 in [-128, 127]; kernel: (kh, kw) int32 in [-128, 127].
    Accumulation is exact int32 (the MAC adder is exact in the paper).
    Reference implementation — the batched pipeline is :func:`conv2d_batched`.
    """
    kh, kw = kernel.shape
    ph, pw = kh // 2, kw // 2
    x = jnp.pad(jnp.asarray(img, jnp.int32), ((ph, ph), (pw, pw)))
    h, w = img.shape
    out = jnp.zeros((h, w), jnp.int32)
    for di in range(kh):
        for dj in range(kw):
            coeff = kernel[di, dj]
            patch = jax.lax.dynamic_slice(x, (di, dj), (h, w))
            out = out + product_fn(patch, jnp.full((), int(coeff), jnp.int32))
    return out


def _im2col(imgs: Array, kh: int, kw: int) -> Array:
    """(B, H, W) int32, zero 'same' padding → (B, H, W, kh·kw) tap patches."""
    b, h, w = imgs.shape
    ph, pw = kh // 2, kw // 2
    x = jnp.pad(imgs, ((0, 0), (ph, ph), (pw, pw)))
    cols = [jax.lax.dynamic_slice(x, (0, di, dj), (b, h, w))
            for di in range(kh) for dj in range(kw)]
    return jnp.stack(cols, axis=-1)


# im2col patches are (B, H, W, taps); contract the tap axis with the
# flattened kernel — dot_general handles the free dims, no hand 2-D reshape
_CONV_DIMS = (((3,), (0,)), ((), ()))


def _meter_fused(s, imgs: Array, kernel_arr: Array, site=None) -> None:
    """Telemetry for the fused conv path, which bypasses ``dot_general``.

    Records the contraction the fused kernel performs — per pixel, one
    tap-axis dot: ``(B, H·W, kh·kw) @ (kh·kw, 1)`` — on the ambient meter,
    so fused and im2col runs report identical MAC/energy totals. The
    opt-in error probe samples a small leading-rows im2col slab (the
    fused kernel contracts the same zero-padded tap products, so the
    per-product error model is the same).
    """
    from repro.obs.meter import current_meter

    meter = current_meter()
    if meter is None:
        return
    b, h, w = imgs.shape
    kh, kw = kernel_arr.shape
    meter.record_contraction(s.meta, b, h * w, kh * kw, 1, site=site)
    if meter.error_probe and s.meta.mult_name != "exact":
        slab = _im2col(imgs[:1, :8], kh, kw)  # (1, ≤8, W, taps)
        meter.probe(s.meta, s.scalar, slab.reshape(1, -1, kh * kw),
                    kernel_arr.reshape(1, kh * kw, 1), site=site)


def conv2d_batched(imgs: Array, kernel: Array,
                   substrate: "str | object" = "approx_bitexact",
                   partitioning=None, fused: "bool | None" = None,
                   site=None) -> Array:
    """Batched 'same' integer convolution via im2col + substrate contraction.

    imgs: (B, H, W) or NHWC (B, H, W, C) int32 in [-128, 127] (channels are
    convolved independently with the same kernel); kernel: (kh, kw) int32.
    substrate: spec string or ProductSubstrate; the contraction is one
    ``substrate.dot_general`` over the (B, H, W, kh·kw) tap patches —
    MXU/Pallas-friendly instead of a Python tap loop. Accumulation is exact
    int32; f(0,0) padding artifacts of the contraction are corrected inside
    the substrates. ``partitioning``: optional
    :class:`repro.nn.substrate.Partitioning` — shards the contraction
    through shard_map (bit-identical for bit-exact substrates). Returns
    int32 of imgs' shape.

    ``site`` optionally names the contraction site for per-site telemetry
    attribution (see :mod:`repro.nn.plan`); it never affects values.

    ``fused`` selects the substrate's fused conv kernel (in-kernel im2col,
    no host-side patch tensor — ``kernels/fused_conv``): ``None`` (default)
    auto-picks it whenever the substrate exposes ``fused_conv2d`` (the
    Pallas backends), no partitioning was requested, and the kernel taps
    are concrete (a traced kernel cannot specialize the fused kernel);
    ``True`` forces it (raising where unavailable); ``False`` forces the
    im2col reference path. Both paths are bit-identical — the fused kernel
    contracts exactly the same zero-padded tap products in the same int32
    ring.
    """
    from repro.nn import substrate as sub

    s = sub.as_substrate(substrate)
    imgs = jnp.asarray(imgs, jnp.int32)
    nhwc = imgs.ndim == 4
    if nhwc:  # fold channels into the batch: depthwise, shared kernel
        b, h, w, c = imgs.shape
        imgs = imgs.transpose(0, 3, 1, 2).reshape(b * c, h, w)
    if imgs.ndim != 3:
        raise ValueError(f"imgs must be (B,H,W) or (B,H,W,C); got {imgs.shape}")
    # concreteness is judged on the caller's object: a closed-over constant
    # kernel stays fused-eligible inside an outer jit (jnp.asarray would
    # re-wrap it as a tracer there), while a jit *argument* falls back
    taps_concrete = not isinstance(kernel, jax.core.Tracer)
    kernel_arr = jnp.asarray(kernel, jnp.int32)
    kh, kw = kernel_arr.shape
    if fused is None:
        fused = (partitioning is None and hasattr(s, "fused_conv2d")
                 and taps_concrete)
    if fused:
        if not hasattr(s, "fused_conv2d"):
            raise ValueError(
                f"fused=True but substrate {s.meta.spec} has no fused conv "
                "kernel (only the Pallas backends do); use fused=False")
        if partitioning is not None:
            raise ValueError(
                "fused=True is incompatible with partitioning — the fused "
                "kernel contracts K in full inside one device kernel")
        _meter_fused(s, imgs, kernel_arr, site=site)
        out = s.fused_conv2d(imgs, kernel)
    else:
        patches = _im2col(imgs, kh, kw)  # (B, H, W, kh·kw)
        spec = sub.ContractionSpec(_CONV_DIMS, partitioning=partitioning,
                                   site=site)
        out = s.dot_general(patches, kernel_arr.reshape(kh * kw, 1),
                            spec)[..., 0]
    if nhwc:
        out = out.reshape(b, c, h, w).transpose(0, 2, 3, 1)
    return out


def edge_detect(img_u8: Array, mult_name: str = "proposed") -> Array:
    """Laplacian edge map with the named multiplier; returns uint8 map.

    ``mult_name`` may carry a width suffix (``"proposed@4"``) or be a
    ``csp_*`` alias; pixels are mapped into that width's operand domain.
    Single-image reference path (tap loop); see :func:`edge_detect_batched`.
    """
    _, fn, n = mult.resolve_multiplier(mult_name)
    px = to_signed_pixels(img_u8, n)
    raw = conv2d_int(px, jnp.asarray(LAPLACIAN), fn)
    return jnp.clip(_rescale_raw(raw, n), 0, 255).astype(jnp.uint8)


def edge_detect_batched(imgs_u8: Array,
                        substrate: "str | object" = "approx_bitexact",
                        partitioning=None) -> Array:
    """Laplacian edge maps for a whole batch under one substrate.

    imgs_u8: (B, H, W) uint8. substrate: spec string (may carry a wiring +
    width suffix, e.g. ``"approx_lut:design_du2022"`` or
    ``"approx_lut:csp_axc1@4"``) or ProductSubstrate. Pixels are mapped
    into the substrate's operand width and the response rescaled back to
    the 8-bit output range. Per-image outputs are bit-identical to
    :func:`edge_detect` for every scalar-faithful substrate — including
    under a :class:`repro.nn.substrate.Partitioning` (the sharded
    contraction stays bit-identical for bit-exact substrates). Returns
    (B, H, W) uint8.
    """
    from repro.nn import substrate as sub

    s = sub.as_substrate(substrate)
    n = getattr(s.meta, "width", 8)
    px = to_signed_pixels(imgs_u8, n)
    raw = conv2d_batched(px, jnp.asarray(LAPLACIAN), s,
                         partitioning=partitioning, site=EDGE_SITE)
    return jnp.clip(_rescale_raw(raw, n), 0, 255).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# planned (multi-site) edge detection
# ---------------------------------------------------------------------------

#: site name of the uniform whole-kernel edge contraction
EDGE_SITE = "conv.edge"

#: the planned path's tap groups: each is a *split* of the 3×3 Laplacian —
#: (site leaf, flat tap indices into the row-major kernel). The center tap
#: (coefficient 8) dominates the response; the ring taps (all −1) are the
#: smoothing term and tolerate cheaper substrates.
_EDGE_TAP_GROUPS = (("center", (4,)), ("ring", (0, 1, 2, 3, 5, 6, 7, 8)))


def edge_tap_sites() -> tuple:
    """The planned edge workload's site names (``conv.edge.<group>``)."""
    return tuple(f"{EDGE_SITE}.{name}" for name, _ in _EDGE_TAP_GROUPS)


def edge_detect_planned(imgs_u8: Array, plan, partitioning=None) -> Array:
    """Laplacian edge maps under a per-site :class:`~repro.nn.plan.SubstratePlan`.

    The 3×3 conv splits into tap groups — ``conv.edge.center`` (the ×8
    tap) and ``conv.edge.ring`` (the eight −1 taps) — each contracted on
    the substrate the plan assigns to its site, then summed in the exact
    int32 adder. Because every substrate corrects its f(0,0) k-padding
    compensation internally, the group responses add up *bit-identically*
    to the single whole-kernel contraction whenever both groups share one
    substrate — so a uniform plan reproduces
    :func:`edge_detect_batched` exactly (asserted in tests), and the
    serving bit-identity contract (zero-pad + row-independence) carries
    over unchanged to mixed plans.

    Per-group widths ≤ 8 rescale by *left* shifts, which distribute over
    the exact adder — mixing widths above 8 would make the final
    right-shift non-distributive, so the autotuner searches widths ≤ 8.
    """
    from repro.nn import plan as plan_mod
    from repro.nn import substrate as sub

    plan = plan_mod.as_plan(plan)
    lap = LAPLACIAN.reshape(-1)
    total = None
    for name, taps in _EDGE_TAP_GROUPS:
        site = f"{EDGE_SITE}.{name}"
        s = sub.get_substrate(plan.resolve(site))
        n = getattr(s.meta, "width", 8)
        px = to_signed_pixels(imgs_u8, n)
        patches = _im2col(px, 3, 3)[..., list(taps)]
        coeffs = jnp.asarray(lap[list(taps)].reshape(len(taps), 1))
        spec = sub.ContractionSpec(_CONV_DIMS, partitioning=partitioning,
                                   site=site)
        raw = s.dot_general(patches, coeffs, spec)[..., 0]
        r = _rescale_raw(raw, n)
        total = r if total is None else total + r
    return jnp.clip(total, 0, 255).astype(jnp.uint8)


def psnr(ref: Array, test: Array, peak: float = 255.0) -> float:
    """PSNR in dB between two uint8 images (paper Fig. 9 metric).

    Computed in float32 explicitly (f64 is unavailable without
    ``jax_enable_x64`` and requesting it only triggered dtype warnings);
    uint8 differences are exactly representable in f32 and the mean over any
    realistic image size stays well inside f32 precision.
    """
    r = jnp.asarray(ref, jnp.float32)
    t = jnp.asarray(test, jnp.float32)
    mse = jnp.mean((r - t) ** 2)
    return float(jnp.where(mse == 0, jnp.inf, 10.0 * jnp.log10(peak**2 / mse)))


def conv2d_float(x: Array, kernel: Array) -> Array:
    """Float reference conv ('same', zero pad) used by NN-layer tests."""
    kh, kw = kernel.shape
    xp = jnp.pad(x, ((kh // 2, kh // 2), (kw // 2, kw // 2)))
    out = jnp.zeros_like(x)
    for di in range(kh):
        for dj in range(kw):
            out = out + kernel[di, dj] * jax.lax.dynamic_slice(xp, (di, dj), x.shape)
    return out
