"""Per-site substrate plans: which multiplier runs *where* in a model.

A :class:`SubstratePlan` maps contraction **sites** — stable dotted names
like ``layer.3.attn.wq`` or ``conv.edge.center`` — to substrate specs
``backend[:mult_name[@N]]`` (the :mod:`repro.nn.substrate` grammar). It is
the per-layer generalization of the historical single ``cfg.dot_mode``
string: a default rule plus glob-style overrides, so one model can run its
attention projections on ``approx_bitexact:proposed@8``, its FFN on a
cheaper width, and everything unnamed on the default.

Site names
----------

Sites are dotted paths pushed by the model code (:func:`site_scope`) around
each :func:`repro.models.common.dense` / conv contraction:

* LM / VLM:   ``layer.{i}.attn.{wq,wk,wv,wo}``, ``layer.{i}.ffn.{wg,wi,wo}``,
  ``layer.{i}.moe.shared.{…}``, ``patch_proj``
* enc-dec:    ``enc.{i}.attn.*``, ``dec.{i}.self.attn.*``,
  ``dec.{i}.cross.attn.*``, ``dec.{i}.cross.{wk,wv}``, ``dec.{i}.ffn.*``
* xLSTM:      ``layer.{i}.{mlstm,slstm}.{wq,…,wo}``
* zamba:      ``layer.{i}.mamba.{in_proj,out_proj}``, ``shared.attn.*``
* edge conv:  ``conv.edge`` (uniform path) and ``conv.edge.{center,ring}``
  (the planned tap-group path — see :func:`repro.nn.conv.edge_detect_planned`).

Resolution
----------

``plan.resolve(site)`` picks the **most specific** matching rule:

1. an exact (wildcard-free) pattern beats any glob;
2. among globs, the one with the most literal (non-wildcard) characters
   wins — ``layer.3.attn.*`` beats ``layer.*``;
3. exact ties go to the **later** rule (so appended overrides win);
4. no match → the plan default.

Patterns are :func:`fnmatch.fnmatchcase` globs; note ``*`` matches dots, so
``layer.*`` covers ``layer.3.attn.wq``. Resolution is lru-cached on the
(hashable) ``(plan, site)`` pair — per-call overhead after the first hit is
one dict lookup, same contract as ``get_substrate``.

Layers under ``lax.scan``
-------------------------

Stacked-parameter layers trace *once* for all repeats, so a per-layer
assignment cannot be baked into the traced spec string. The model body
wraps each scanned layer in :func:`scan_site_scope`, carrying the traced
repeat index plus the concrete per-repeat site names; :func:`dispatch` then
resolves every candidate site and either (a) collapses to one static
substrate when all repeats agree — the common case, zero runtime cost — or
(b) returns the distinct substrate groups plus a ``branch_of`` table the
caller lowers through ``jax.lax.switch`` on the carried index.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import functools
import json
import os
import threading
from typing import Any, Dict, Iterable, Optional, Tuple

from repro.nn import substrate as psub

__all__ = [
    "SubstratePlan", "as_plan", "load_plan", "save_plan",
    "stat_spec", "stat_plan",
    "site_scope", "scan_site_scope", "current_sites", "dispatch",
    "plan_override_scope", "current_plan_override",
    "SiteDispatch", "PLAN_SCHEMA_VERSION",
]

PLAN_SCHEMA_VERSION = 1

_WILDCARDS = "*?["


def _check_spec(spec: str) -> str:
    """Eager spec validation: grammar + a registered backend name.

    Wirings/widths are validated lazily by the backend factories
    (``get_substrate``) — they own the per-backend width support matrix.
    """
    parts = psub.parse_spec(spec)
    known = psub.list_substrates()
    if parts.backend not in known:
        raise ValueError(
            f"plan names unknown substrate backend {parts.backend!r} "
            f"(known: {known})")
    return spec


def _norm_rules(rules) -> Tuple[Tuple[str, str], ...]:
    if isinstance(rules, dict):
        rules = tuple(rules.items())
    out = []
    for rule in rules:
        if isinstance(rule, dict):
            pat, spec = rule["site"], rule["spec"]
        else:
            pat, spec = rule
        pat, spec = str(pat), str(spec)
        if not pat:
            raise ValueError("plan rule has an empty site pattern")
        _check_spec(spec)
        out.append((pat, spec))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SubstratePlan:
    """Site-addressed substrate assignment: default spec + glob overrides.

    default: substrate spec for sites no rule matches.
    rules:   ordered ``(site_pattern, spec)`` pairs; also accepts a dict or
             ``{"site": …, "spec": …}`` mappings at construction. Most
             specific pattern wins (see module docstring).

    Hashable by value, so plans key lru caches and can live on a (frozen)
    ``ModelConfig``.
    """

    default: str = "exact"
    rules: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        _check_spec(self.default)
        object.__setattr__(self, "default", str(self.default))
        object.__setattr__(self, "rules", _norm_rules(self.rules))

    # -- resolution ----------------------------------------------------------

    def resolve(self, site: Optional[str]) -> str:
        """The substrate spec assigned to ``site`` (default when None)."""
        if site is None:
            return self.default
        return _resolve(self, str(site))

    def substrate_for(self, site: Optional[str]) -> psub.ProductSubstrate:
        return psub.get_substrate(self.resolve(site))

    @property
    def is_uniform(self) -> bool:
        return not self.rules

    @property
    def label(self) -> str:
        """Compact human-readable identity for logs/trace spans."""
        if self.is_uniform:
            return f"plan({self.default})"
        return f"plan({self.default}+{len(self.rules)} rules)"

    # -- construction / serialization ----------------------------------------

    @classmethod
    def uniform(cls, spec: str) -> "SubstratePlan":
        """A plan equivalent to the legacy single ``dot_mode`` string."""
        return cls(default=str(spec))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": PLAN_SCHEMA_VERSION,
            "default": self.default,
            "rules": [{"site": p, "spec": s} for p, s in self.rules],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SubstratePlan":
        version = int(d.get("version", PLAN_SCHEMA_VERSION))
        if version > PLAN_SCHEMA_VERSION:
            raise ValueError(
                f"plan schema version {version} is newer than supported "
                f"({PLAN_SCHEMA_VERSION})")
        return cls(default=d.get("default", "exact"),
                   rules=_norm_rules(d.get("rules", ())))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "SubstratePlan":
        return cls.from_dict(json.loads(s))


def as_plan(p: "SubstratePlan | str | dict") -> SubstratePlan:
    """Accept a plan, a spec string (→ uniform plan), or a plan dict."""
    if isinstance(p, SubstratePlan):
        return p
    if isinstance(p, str):
        return SubstratePlan.uniform(p)
    if isinstance(p, dict):
        return SubstratePlan.from_dict(p)
    raise TypeError(f"cannot interpret {type(p).__name__} as a SubstratePlan")


def save_plan(path: str, plan: SubstratePlan) -> str:
    """Write ``plan`` as JSON (see docs/plans.md for the schema)."""
    with open(path, "w") as f:
        json.dump(as_plan(plan).to_dict(), f, indent=2)
        f.write("\n")
    return path


def load_plan(path: str) -> SubstratePlan:
    """Read a plan from a JSON file, or from ``plan.json`` in a bundle dir."""
    if os.path.isdir(path):
        path = os.path.join(path, "plan.json")
    with open(path) as f:
        return SubstratePlan.from_dict(json.load(f))


# backends with an approx_stat statistical counterpart (same wiring + width)
_STAT_REWRITABLE = ("approx_bitexact", "approx_lut", "approx_pallas")


def stat_spec(spec: str) -> str:
    """A spec's fast statistical counterpart: same wiring/width, stat model.

    Used wherever a cheap stand-in for a bit-exact wiring is wanted — the
    autotuner's candidate scoring and the QAT ``forward="stat"`` training
    path both rewrite through here. Specs without a stat counterpart
    (``exact``, ``int8``, ``approx_stat`` itself) pass through unchanged.
    """
    parts = psub.parse_spec(spec)
    if parts.backend in _STAT_REWRITABLE:
        return f"approx_stat:{parts.mult_name}@{parts.width}"
    return spec


def stat_plan(plan: SubstratePlan) -> SubstratePlan:
    """``plan`` with every assignment rewritten via :func:`stat_spec`."""
    plan = as_plan(plan)
    return SubstratePlan(default=stat_spec(plan.default),
                         rules=tuple((p, stat_spec(s)) for p, s in plan.rules))


# ---------------------------------------------------------------------------
# rule matching (most-specific wins)
# ---------------------------------------------------------------------------


def _specificity(pattern: str) -> Tuple[int, int]:
    """(tier, literal-char count): exact patterns outrank every glob."""
    if not any(c in pattern for c in _WILDCARDS):
        return (2, len(pattern))
    literals = sum(1 for c in pattern if c not in _WILDCARDS)
    return (1, literals)


@functools.lru_cache(maxsize=None)
def _resolve(plan: SubstratePlan, site: str) -> str:
    best_spec, best_score = None, None
    for pattern, spec in plan.rules:
        if not fnmatch.fnmatchcase(site, pattern):
            continue
        score = _specificity(pattern)
        if best_score is None or score >= best_score:  # later rule wins ties
            best_spec, best_score = spec, score
    return plan.default if best_spec is None else best_spec


# ---------------------------------------------------------------------------
# ambient plan override (thread-local, mirrors partitioning_scope)
# ---------------------------------------------------------------------------


_PLAN_OVERRIDE_STATE = threading.local()


def current_plan_override() -> Optional[SubstratePlan]:
    """The ambient plan installed by :func:`plan_override_scope`, or None.

    Read at *trace* time by call sites that resolve their substrate from a
    config-carried plan (:func:`repro.models.common.substrate_plan`).
    """
    return getattr(_PLAN_OVERRIDE_STATE, "value", None)


@contextlib.contextmanager
def plan_override_scope(plan: "SubstratePlan | str | dict | None"):
    """Make ``plan`` govern every plan-consulting contraction in the block.

    While active, :func:`repro.models.common.substrate_plan` returns this
    plan instead of the model config's ``dot_plan``/``dot_mode`` — the hook
    by which a layer *above* an already-built model function (e.g. a
    :class:`repro.train.loop.TrainLoop` resuming from a checkpoint whose
    manifest pins different numerics) can change which substrate each site
    resolves to without rebuilding the model. Trace-time ambient: wrap the
    call being traced, exactly like
    :func:`repro.nn.substrate.dot_override_scope`. ``None`` is a no-op
    scope.
    """
    prev = getattr(_PLAN_OVERRIDE_STATE, "value", None)
    _PLAN_OVERRIDE_STATE.value = as_plan(plan) if plan is not None else None
    try:
        yield _PLAN_OVERRIDE_STATE.value
    finally:
        _PLAN_OVERRIDE_STATE.value = prev


# ---------------------------------------------------------------------------
# ambient site scopes (thread-local, mirrors partitioning_scope)
# ---------------------------------------------------------------------------


class _ScanFrame:
    """A scan-carried site segment: traced repeat index + per-repeat names."""

    __slots__ = ("index", "names")

    def __init__(self, index, names: Tuple[str, ...]):
        self.index = index
        self.names = names


_SITE_STATE = threading.local()


def _stack() -> list:
    st = getattr(_SITE_STATE, "stack", None)
    if st is None:
        st = _SITE_STATE.stack = []
    return st


@contextlib.contextmanager
def site_scope(*parts):
    """Push concrete site path segment(s) for the duration of the block.

    ``site_scope("layer.3", "attn")`` makes a ``dense(..., site="wq")``
    inside resolve at ``layer.3.attn.wq``. Segments must not contain glob
    wildcards (those belong in plan *rules*, not site names).
    """
    st = _stack()
    pushed = 0
    try:
        for p in parts:
            p = str(p)
            if not p or any(c in p for c in _WILDCARDS):
                raise ValueError(f"invalid site segment {p!r}")
            st.append(p)
            pushed += 1
        yield
    finally:
        del st[len(st) - pushed:]


@contextlib.contextmanager
def scan_site_scope(index, names: Iterable[str]):
    """Push a scan frame: traced repeat ``index`` selecting among ``names``.

    ``names[i]`` is the site segment the body occupies on repeat ``i``.
    At most one scan frame may be active (models scan one layer stack);
    nesting a second raises.
    """
    names = tuple(str(n) for n in names)
    if not names:
        raise ValueError("scan_site_scope needs at least one repeat name")
    st = _stack()
    if any(isinstance(e, _ScanFrame) for e in st):
        raise RuntimeError("nested scan_site_scope frames are not supported")
    st.append(_ScanFrame(index, names))
    try:
        yield
    finally:
        st.pop()


def current_sites(leaf: Optional[str] = None):
    """The candidate site names at this point, given a final ``leaf`` segment.

    Returns ``(scan_index, candidates)``: outside any scan frame the index
    is None and candidates has exactly one entry (possibly ``""`` when no
    scope is active and no leaf given); inside a frame there is one
    candidate per repeat, in repeat order.
    """
    pre, post, frame = [], [], None
    for entry in _stack():
        if isinstance(entry, _ScanFrame):
            frame = entry
        elif frame is None:
            pre.append(entry)
        else:
            post.append(entry)
    tail = post + ([str(leaf)] if leaf is not None else [])
    if frame is None:
        return None, (".".join(pre + tail),)
    return frame.index, tuple(".".join(pre + [n] + tail)
                              for n in frame.names)


# ---------------------------------------------------------------------------
# dispatch: plan × ambient sites → static substrate or switch groups
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteDispatch:
    """Resolved execution choice for one contraction call site.

    index:     None → static single-substrate call; otherwise the traced
               scan index to branch on.
    groups:    ``(spec, site_label)`` per distinct assignment (one entry
               when static). ``site_label`` is the meter attribution name
               (None → anonymous, falls back to the shape label).
    branch_of: per-repeat group id (len = number of scanned repeats), only
               when ``index`` is not None.
    """

    index: Any
    groups: Tuple[Tuple[str, Optional[str]], ...]
    branch_of: Optional[Tuple[int, ...]] = None


def _condense(names) -> str:
    """One display label covering several sites: common prefix + ``*``."""
    names = list(names)
    if len(set(names)) == 1:
        return names[0]
    prefix = os.path.commonprefix(names)
    reversed_suffix = os.path.commonprefix([n[::-1] for n in names])
    max_suffix = min(len(n) for n in names) - len(prefix)
    suffix = reversed_suffix[::-1][-max_suffix:] if max_suffix > 0 else ""
    return f"{prefix}*{suffix}"


def dispatch(plan: SubstratePlan, leaf: Optional[str] = None) -> SiteDispatch:
    """Resolve ``plan`` against the ambient site scopes for one call site."""
    index, candidates = current_sites(leaf)
    if index is None:
        site = candidates[0]
        return SiteDispatch(None, ((plan.resolve(site), site or None),))
    specs = [plan.resolve(c) for c in candidates]
    group_ids: Dict[str, int] = {}
    members: Dict[int, list] = {}
    branch_of = []
    for i, spec in enumerate(specs):
        gid = group_ids.setdefault(spec, len(group_ids))
        branch_of.append(gid)
        members.setdefault(gid, []).append(i)
    if len(group_ids) == 1:
        return SiteDispatch(None, ((specs[0], _condense(candidates)),))
    labels = {}
    for spec, gid in group_ids.items():
        label = _condense([candidates[i] for i in members[gid]])
        if label in labels.values():  # two groups condensed identically
            label = f"{label}#{gid}"
        labels[gid] = label
    groups = tuple((spec, labels[gid]) for spec, gid in group_ids.items())
    return SiteDispatch(index, groups, tuple(branch_of))
