"""Matrix multiplication under the paper's approximate multiplier (façade).

Thin compatibility layer over :mod:`repro.nn.substrate` — all product-mode
selection goes through the :class:`~repro.nn.substrate.ProductSubstrate`
registry; this module keeps the historical function signatures and adds a
spec-string front door for the ``dot_general`` contraction surface.

Execution modes (= registered substrates, selectable per layer / per config):

* ``exact``          — plain dot in the compute dtype (fp reference).
* ``int8``           — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact``— width-N quantization, every scalar product evaluated
                       with the paper's multiplier closed form. Bit-identical
                       to the hardware netlist; O(M·K·N) scalar-product work,
                       for validation / small models / the edge-detection app.
* ``approx_lut``     — same contraction through the (2^N)² product LUT
                       (gather-based; asserted equal to approx_bitexact;
                       256×256 at the default N=8).
* ``approx_stat``    — exact int32 matmul + *separable statistical error
                       model*: E[e(a,b)] ≈ r[a] + c[b] − µ. MXU-friendly
                       deployment-scale stand-in. Beyond-paper contribution.
* ``approx_pallas``  — the tiled Pallas TPU kernels: the closed-form
                       kernel (``kernels/approx_matmul``) for proposed@8,
                       the LUT-input kernel (``kernels/lut_matmul``) for
                       every other wiring at widths 3..8; interpret-mode
                       fallback off-TPU, bit-identical to
                       ``approx_bitexact``.

A mode string may carry a multiplier wiring + width suffix
(``"approx_lut:design_du2022"``, ``"approx_bitexact:proposed@16"``); see
:func:`repro.nn.substrate.get_substrate` for the full
``backend[:mult_name[@N]]`` grammar.

Naming note: :func:`approx_matmul_int` is the canonical integer-contraction
entry point — operands are int8 at widths ≤ 8 but int16 at wider widths, so
the historical ``approx_matmul_int8`` name survives only as a deprecated
alias (same for ``ProductSubstrate.dot_int`` vs ``dot_int8``).

NOTE: the approximate multiplier maps (0,0) → +compensation_constant(N)
(the constant fires regardless of operands — true to the netlist; +192 at
the default N=8), so padded/zero entries still contribute; the substrates'
contraction helpers mask accordingly — including per K-shard when a
:class:`~repro.nn.substrate.Partitioning` shards the contraction dim.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax.numpy as jnp

from repro.nn import substrate as sub

Array = jnp.ndarray
Mode = Literal["exact", "int8", "approx_bitexact", "approx_lut",
               "approx_stat", "approx_pallas"]


def approx_dot_general(x: Array, w: Array,
                       spec: "Optional[sub.ContractionSpec]" = None,
                       mode: Mode = "exact",
                       mult_name: str | None = None) -> Array:
    """General contraction under the chosen mode (spec-string front door).

    ``spec`` is a :class:`~repro.nn.substrate.ContractionSpec` — dimension
    numbers, :class:`~repro.nn.substrate.QuantPolicy`, and
    :class:`~repro.nn.substrate.Partitioning`; None means plain integer
    matmul dims. mult_name defaults to the mode string's suffix, else
    ``"proposed"``.
    """
    return sub.get_substrate(mode, mult_name=mult_name).dot_general(x, w, spec)


def approx_matmul_int(a: Array, b: Array, mode: Mode = "approx_bitexact",
                      mult_name: str | None = None) -> Array:
    """Integer-domain (M,K)@(K,N) contraction under the chosen mode.

    Operands are int8 at widths ≤ 8, int16 at wider widths.
    mult_name defaults to the mode string's suffix, else ``"proposed"``.
    """
    return sub.get_substrate(mode, mult_name=mult_name).dot_int(a, b)


def approx_matmul_int8(a8: Array, b8: Array, mode: Mode = "approx_bitexact",
                       mult_name: str | None = None) -> Array:
    """Deprecated alias of :func:`approx_matmul_int` (the ``int8`` name was
    a lie at N=16, where operands are int16)."""
    return approx_matmul_int(a8, b8, mode=mode, mult_name=mult_name)


def approx_dot(x: Array, w: Array, mode: Mode = "exact",
               mult_name: str | None = None) -> Array:
    """``x @ w`` with the paper's multiplier as the scalar-product unit.

    x: (..., K) activations (any float dtype); w: (K, N) weights.
    Activations use a per-tensor dynamic scale; weights per-output-channel
    (= ``dot_general`` with the default ``QuantPolicy``). Returns the
    result in x's dtype.
    """
    return sub.get_substrate(mode, mult_name=mult_name).dot(x, w)
