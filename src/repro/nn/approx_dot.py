"""Matrix multiplication under the paper's approximate multiplier (façade).

Thin compatibility layer over :mod:`repro.nn.substrate` — all product-mode
selection goes through the :class:`~repro.nn.substrate.ProductSubstrate`
registry; this module keeps the historical function signatures.

Execution modes (= registered substrates, selectable per layer / per config):

* ``exact``          — plain dot in the compute dtype (fp reference).
* ``int8``           — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact``— int8 quantization, every scalar product evaluated with
                       the paper's multiplier closed form. Bit-identical to
                       the hardware netlist; O(M·K·N) scalar-product work, for
                       validation / small models / the edge-detection app.
* ``approx_lut``     — same contraction through the 256×256 product LUT
                       (gather-based; asserted equal to approx_bitexact).
* ``approx_stat``    — exact int32 matmul + *separable statistical error
                       model*: E[e(a,b)] ≈ r[a] + c[b] − µ. MXU-friendly
                       deployment-scale stand-in. Beyond-paper contribution.
* ``approx_pallas``  — the tiled Pallas TPU kernels: the closed-form
                       kernel (``kernels/approx_matmul``) for proposed@8,
                       the LUT-input kernel (``kernels/lut_matmul``) for
                       every other wiring at widths 3..8; interpret-mode
                       fallback off-TPU, bit-identical to
                       ``approx_bitexact``.

A mode string may carry a multiplier wiring + width suffix
(``"approx_lut:design_du2022"``, ``"approx_bitexact:proposed@16"``); see
:func:`repro.nn.substrate.get_substrate` for the full
``backend[:mult_name[@N]]`` grammar.

NOTE: the approximate multiplier maps (0,0) → +192 (compensation constant
fires regardless of operands — true to the netlist), so padded/zero entries
still contribute; the substrates' contraction helpers mask accordingly.
"""
from __future__ import annotations

from typing import Literal

import jax.numpy as jnp

from repro.nn import substrate as sub

Array = jnp.ndarray
Mode = Literal["exact", "int8", "approx_bitexact", "approx_lut",
               "approx_stat", "approx_pallas"]


def approx_matmul_int8(a8: Array, b8: Array, mode: Mode = "approx_bitexact",
                       mult_name: str | None = None) -> Array:
    """Integer-domain contraction of int8 operands under the chosen mode.

    mult_name defaults to the mode string's suffix, else ``"proposed"``.
    """
    return sub.get_substrate(mode, mult_name=mult_name).dot_int8(a8, b8)


def approx_dot(x: Array, w: Array, mode: Mode = "exact",
               mult_name: str | None = None) -> Array:
    """``x @ w`` with the paper's multiplier as the scalar-product unit.

    x: (..., K) activations (any float dtype); w: (K, N) weights.
    Activations use a per-tensor dynamic scale; weights per-output-channel.
    Returns the result in x's dtype.
    """
    return sub.get_substrate(mode, mult_name=mult_name).dot(x, w)
