"""Matrix multiplication under the paper's approximate multiplier.

Execution modes (selectable per layer / per config):

* ``exact``          — plain dot in the compute dtype (fp reference).
* ``int8``           — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact``— int8 quantization, every scalar product evaluated with
                       the paper's multiplier closed form. Bit-identical to
                       the hardware netlist; O(M·K·N) scalar-product work, for
                       validation / small models / the edge-detection app.
* ``approx_lut``     — same contraction through the 256×256 product LUT
                       (gather-based; asserted equal to approx_bitexact).
* ``approx_stat``    — exact int32 matmul + *separable statistical error
                       model*: E[e(a,b)] ≈ r[a] + c[b] − µ, where e is the
                       multiplier's error LUT, r/c its row/column means. Adds
                       two gathers + two rank-1 terms, lowers to MXU-friendly
                       HLO, and is the deployment-scale stand-in used by the
                       multi-pod dry-runs (the Pallas kernel replaces it on
                       real hardware). Beyond-paper contribution.

NOTE: the approximate multiplier maps (0,0) → +192 (compensation constant
fires regardless of operands — true to the netlist), so padded/zero entries
still contribute; contraction helpers mask accordingly where needed.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.nn import quant

Array = jnp.ndarray
Mode = Literal["exact", "int8", "approx_bitexact", "approx_lut", "approx_stat"]

_K_CHUNK = 16  # k-slab size for the bit-exact contraction


@functools.lru_cache(maxsize=None)
def _stat_tables(mult_name: str) -> tuple[np.ndarray, np.ndarray, float]:
    """Separable error model (r[a], c[b], µ) from the error LUT."""
    e = lut_lib.error_lut(mult_name).astype(np.float64)
    mu = e.mean()
    r = e.mean(axis=1) - 0.5 * mu
    c = e.mean(axis=0) - 0.5 * mu
    return r.astype(np.float32), c.astype(np.float32), float(mu)


def _bitexact_contract(a8: Array, b8: Array, product_fn) -> Array:
    """sum_k f(a[m,k], b[k,n]) with f an arbitrary int8×int8→int32 model."""
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2, (a8.shape, b8.shape)
    pad = (-k) % _K_CHUNK
    if pad:
        # pad with zeros, then subtract the spurious f(0,0)=192 contributions
        a8 = jnp.pad(a8, ((0, 0), (0, pad)))
        b8 = jnp.pad(b8, ((0, pad), (0, 0)))
    steps = a8.shape[1] // _K_CHUNK
    a3 = a8.reshape(m, steps, _K_CHUNK).transpose(1, 0, 2).astype(jnp.int32)
    b3 = b8.reshape(steps, _K_CHUNK, n).astype(jnp.int32)

    def body(acc, slabs):
        a_c, b_c = slabs  # (m, ck), (ck, n)
        prod = product_fn(a_c[:, :, None], b_c[None, :, :])  # (m, ck, n)
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (a3, b3))
    if pad:
        f00 = int(product_fn(jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
        acc = acc - f00 * pad
    return acc


def approx_matmul_int8(a8: Array, b8: Array, mode: Mode = "approx_bitexact",
                       mult_name: str = "proposed") -> Array:
    """Integer-domain contraction of int8 operands under the chosen mode."""
    a8 = a8.astype(jnp.int8)
    b8 = b8.astype(jnp.int8)
    if mode == "int8":
        return jax.lax.dot_general(
            a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
    if mode == "approx_bitexact":
        fn = mult.ALL_MULTIPLIERS[mult_name]
        return _bitexact_contract(a8, b8, fn)
    if mode == "approx_lut":
        table = jnp.asarray(lut_lib.build_lut(mult_name))
        return _bitexact_contract(
            a8, b8, lambda x, y: table[x + 128, y + 128]
        )
    if mode == "approx_stat":
        exact = jax.lax.dot_general(
            a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        r, c, _mu = _stat_tables(mult_name)
        k = a8.shape[1]
        ra = jnp.asarray(r)[a8.astype(jnp.int32) + 128].sum(axis=1)  # (m,)
        cb = jnp.asarray(c)[b8.astype(jnp.int32) + 128].sum(axis=0)  # (n,)
        corr = ra[:, None] + cb[None, :]
        return exact + corr.astype(jnp.int32)
    raise ValueError(f"unknown integer mode: {mode}")


def approx_dot(x: Array, w: Array, mode: Mode = "exact",
               mult_name: str = "proposed") -> Array:
    """``x @ w`` with the paper's multiplier as the scalar-product unit.

    x: (..., K) activations (any float dtype); w: (K, N) weights.
    Activations use a per-tensor dynamic scale; weights per-output-channel.
    Returns the result in x's dtype.
    """
    if mode == "exact":
        return jnp.dot(x, w.astype(x.dtype))
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    qx = quant.quantize(x2, axes=None)           # per-tensor scalar scale
    qw = quant.quantize(w, axes=(0,))            # per-output-channel (1, N)
    acc = approx_matmul_int8(qx.values, qw.values, mode=mode, mult_name=mult_name)
    out = acc.astype(jnp.float32) * (qx.scale * qw.scale)
    return out.reshape(*batch_shape, w.shape[-1]).astype(x.dtype)
