"""Symmetric int8 quantization for the approximate-multiplier execution modes.

The paper's multiplier consumes signed 8-bit operands; integrating it into a
neural network therefore requires a quantization boundary. We use standard
symmetric absmax quantization: per-tensor (dynamic) for activations and
per-output-channel (static or dynamic) for weights, matching common int8
inference practice.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

Array = jnp.ndarray

INT8_MAX = 127.0


@dataclasses.dataclass(frozen=True)
class Quantized:
    """int8 values + float scale such that ``values * scale ≈ original``."""

    values: Array  # int8
    scale: Array   # f32, broadcastable against values

    def dequantize(self) -> Array:
        return self.values.astype(jnp.float32) * self.scale


def _absmax(x: Array, axes: Sequence[int] | None) -> Array:
    m = jnp.max(jnp.abs(x), axis=axes, keepdims=True) if axes is not None else jnp.max(jnp.abs(x))
    return jnp.maximum(m.astype(jnp.float32), 1e-8)


def quantize(x: Array, axes: Sequence[int] | None = None) -> Quantized:
    """Symmetric absmax quantization to int8.

    axes: reduction axes for the scale (None = per-tensor). E.g. for a weight
    of shape (in, out), ``axes=(0,)`` gives a per-output-channel scale.
    """
    scale = _absmax(x, axes) / INT8_MAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale)


def fake_quantize(x: Array, axes: Sequence[int] | None = None) -> Array:
    """Quantize→dequantize (straight-through value); used in QAT-style tests."""
    q = quantize(x, axes)
    return q.dequantize().astype(x.dtype)


def quantization_error(x: Array, axes: Sequence[int] | None = None) -> Array:
    return jnp.abs(fake_quantize(x, axes) - x)
