"""Symmetric integer quantization for the approximate-multiplier modes.

The paper's multiplier consumes signed n-bit operands (8-bit in the paper);
integrating it into a neural network therefore requires a quantization
boundary. We use standard symmetric absmax quantization: per-tensor
(dynamic) for activations and per-output-channel (static or dynamic) for
weights, matching common int8 inference practice.

Width contract: ``bits`` selects the operand width of the downstream
multiplier. Values are clipped to ``[-(2^(bits-1)-1), 2^(bits-1)-1]``
(symmetric — the most negative code is unused, as in standard int8
practice) and stored as int8 for bits ≤ 8, int16 for 9 ≤ bits ≤ 16.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

Array = jnp.ndarray


def qmax(bits: int = 8) -> float:
    """Largest symmetric quantized magnitude at the given operand width."""
    if not (2 <= bits <= 16):
        raise ValueError(f"quantization width must be in [2, 16]; got {bits}")
    return float((1 << (bits - 1)) - 1)


def storage_dtype(bits: int = 8):
    """Narrowest jnp integer dtype holding signed ``bits``-wide values."""
    return jnp.int8 if bits <= 8 else jnp.int16


@dataclasses.dataclass(frozen=True)
class Quantized:
    """Integer values + float scale such that ``values * scale ≈ original``."""

    values: Array  # int8 (bits ≤ 8) or int16
    scale: Array   # f32, broadcastable against values

    def dequantize(self) -> Array:
        return self.values.astype(jnp.float32) * self.scale


def _absmax(x: Array, axes: Sequence[int] | None, eps: float = 1e-8) -> Array:
    """Epsilon-guarded absmax: an all-zero tensor yields ``eps``, not 0, so
    the derived scale stays finite and zero tensors quantize to exact zeros
    instead of NaN (0/0)."""
    m = jnp.max(jnp.abs(x), axis=axes, keepdims=True) if axes is not None else jnp.max(jnp.abs(x))
    return jnp.maximum(m.astype(jnp.float32), eps)


def quantize(x: Array, axes: Sequence[int] | None = None,
             bits: int = 8, eps: float = 1e-8) -> Quantized:
    """Symmetric absmax quantization to signed ``bits``-wide integers.

    axes: reduction axes for the scale (None = per-tensor). E.g. for a weight
    of shape (in, out), ``axes=(0,)`` gives a per-output-channel scale.
    eps: degenerate-scale guard (see ``_absmax``).
    """
    m = qmax(bits)
    scale = _absmax(x, axes, eps) / m
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -m, m)
    return Quantized(q.astype(storage_dtype(bits)), scale)


def fake_quantize(x: Array, axes: Sequence[int] | None = None,
                  bits: int = 8) -> Array:
    """Quantize→dequantize (straight-through value); used in QAT-style tests."""
    q = quantize(x, axes, bits)
    return q.dequantize().astype(x.dtype)


def quantization_error(x: Array, axes: Sequence[int] | None = None,
                       bits: int = 8) -> Array:
    return jnp.abs(fake_quantize(x, axes, bits) - x)
