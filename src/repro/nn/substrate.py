"""Product-substrate layer: one registry for every scalar-product unit.

The paper's thesis is that a single scalar-product unit — the sign-focused-
compressor approximate multiplier — can be swapped underneath convolution and
matmul workloads. This module makes that swap a first-class object instead of
stringly-typed ``if mode == ...`` chains: a :class:`ProductSubstrate` bundles
the three contraction capabilities every workload needs

* ``scalar(a, b)``   — the raw intN×intN→int32 product model,
* ``dot_int8(a, b)`` — integer-domain (M,K)@(K,N) contraction (exact adder;
                       the name is historical — operands are int8 for widths
                       ≤ 8 and int16 for wider),
* ``dot(x, w)``      — float-domain matmul through the int-N quantization
                       boundary (per-tensor activations, per-channel weights),
* ``conv2d(imgs,k)`` — batched NHW(C) 'same' convolution via im2col + dot,

plus :class:`SubstrateMeta` (bit-exactness, operand width, preferred
backend, cost hints) so launchers/benchmarks can reason about a substrate
without running it.

Registered backends (``list_substrates()``):

* ``exact``           — float reference dot; exact integer contraction.
* ``int8``            — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact`` — every scalar product through the closed-form
                        multiplier model; bit-identical to the netlist.
                        Any width 3..16.
* ``approx_lut``      — same contraction through the (2^N)² product LUT.
                        Widths ≤ 8 (the table must be enumerable).
* ``approx_stat``     — exact int32 matmul + separable statistical error
                        model (MXU-friendly deployment stand-in). Widths ≤ 8
                        (the model is fit on the exhaustive error LUT).
* ``approx_pallas``   — the tiled Pallas TPU kernels, interpret-mode
                        fallback off-TPU; bit-identical to
                        ``approx_bitexact``. Any wiring at widths 3..8:
                        ``proposed``@8 runs the closed-form kernel
                        (``kernels/approx_matmul``), every other
                        wiring/width the LUT-input kernel
                        (``kernels/lut_matmul``).

Spec grammar — ``"backend[:mult_name[@N]]"`` — selects a backend, a
multiplier wiring, and an operand width at once:

* ``"approx_lut:design_du2022"`` — any name in
  ``core.multiplier.ALL_MULTIPLIERS`` (or a ``csp_*`` alias) is reachable;
* ``"approx_lut:csp_axc1@4"`` / ``"approx_bitexact:proposed@16"`` — the same
  wiring instantiated at 4- or 16-bit operand width;
* a bare backend name defaults to the paper's ``proposed`` wiring at N=8.

Width contract: ``meta.width`` is the operand width N. Integer operands
outside the signed N-bit range are **wrapped** (low N bits, sign-extended)
by every approx backend, so bitexact/LUT stay bit-identical on arbitrary
ints; the float ``dot`` path quantizes into range so wrapping never fires.
N=4 and N=8 models are exhaustively verified against the structural netlist
model in tests; N=16 is verified on random samples.

Accumulator contract: every integer contraction accumulates in int32 (JAX
runs without x64 here), i.e. sums are exact until they exceed ±2^31 and
wrap mod 2^32 beyond that. At N ≤ 8 no realistic K overflows; at N=16 the
worst-case product is ~2^30, so keep K·|products| below 2^31 (edge-detection
taps and quantized convs do) — ``scalar_faithful`` parity is defined modulo
2^32.

NOTE: the approximate multiplier maps (0,0) → +compensation_constant(N)
(the constant fires regardless of operands — true to the netlist; +192 at
N=8), so zero padding of the contraction dimension injects spurious
contributions; every backend corrects for f(0,0) where it pads.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.nn import quant

Array = jnp.ndarray

_K_CHUNK = 16  # k-slab size for the bit-exact contraction


# ---------------------------------------------------------------------------
# Protocol + metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubstrateMeta:
    """Static facts about a substrate, for dispatch-free reasoning.

    bit_exact:        product values are bit-identical to the hardware netlist
                      (exact backends are trivially bit-exact to *their* model).
    scalar_faithful:  ``dot_int8(a, b) == Σ_k scalar(a_k, b_k)`` exactly —
                      holds for everything except the statistical error model,
                      which is defined at contraction level (one rounding of
                      the separable correction per output element).
    preferred_backend: "tpu" for kernels that only pay off on real hardware,
                      "any" otherwise.
    cost_hint:        dominant execution resource: "mxu" | "vpu" | "gather" |
                      "scalar-emulation".
    width:            operand width N of the scalar-product unit (bits).
    """

    name: str
    mult_name: str
    bit_exact: bool
    scalar_faithful: bool
    preferred_backend: str
    cost_hint: str
    width: int = mult.N_BITS

    @property
    def mult_key(self) -> str:
        """Wiring + width key, as it appears in spec strings (``@8`` implicit)."""
        if self.width == mult.N_BITS:
            return self.mult_name
        return f"{self.mult_name}@{self.width}"

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.mult_key}"

    @property
    def label(self) -> str:
        """Short display name: bare backend for default wirings at default
        width, full spec otherwise (keeps benchmark row names distinct)."""
        if self.mult_name in ("exact", "proposed") and self.width == mult.N_BITS:
            return self.name
        return self.spec


@runtime_checkable
class ProductSubstrate(Protocol):
    """Anything with the four contraction capabilities + metadata."""

    meta: SubstrateMeta

    def scalar(self, a: Array, b: Array) -> Array: ...

    def dot_int8(self, a8: Array, b8: Array) -> Array: ...

    def dot(self, x: Array, w: Array) -> Array: ...

    def conv2d(self, imgs: Array, kernel: Array) -> Array: ...


# ---------------------------------------------------------------------------
# Shared contraction machinery
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _stat_tables(mult_key: str) -> tuple[np.ndarray, np.ndarray, float]:
    """Separable error model (r[a], c[b], µ) from the width-N error LUT."""
    e = lut_lib.error_lut(mult_key).astype(np.float64)
    mu = e.mean()
    r = e.mean(axis=1) - 0.5 * mu
    c = e.mean(axis=0) - 0.5 * mu
    return r.astype(np.float32), c.astype(np.float32), float(mu)


def _bitexact_contract(a8: Array, b8: Array, product_fn,
                       f00: int | None = None) -> Array:
    """sum_k f(a[m,k], b[k,n]) with f an arbitrary intN×intN→int32 model.

    ``f00``: the model's f(0,0) value, needed to correct k-padding. Callers
    that know it statically pass it so the contraction stays traceable (the
    serving path jits whole ``edge_detect_batched`` calls through here);
    when omitted it is constant-folded out of the trace.
    """
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2, (a8.shape, b8.shape)
    pad = (-k) % _K_CHUNK
    if pad:
        # pad with zeros, then subtract the spurious f(0,0) contributions
        a8 = jnp.pad(a8, ((0, 0), (0, pad)))
        b8 = jnp.pad(b8, ((0, pad), (0, 0)))
    steps = a8.shape[1] // _K_CHUNK
    a3 = a8.reshape(m, steps, _K_CHUNK).transpose(1, 0, 2).astype(jnp.int32)
    b3 = b8.reshape(steps, _K_CHUNK, n).astype(jnp.int32)

    def body(acc, slabs):
        a_c, b_c = slabs  # (m, ck), (ck, n)
        prod = product_fn(a_c[:, :, None], b_c[None, :, :])  # (m, ck, n)
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (a3, b3))
    if pad:
        if f00 is None:
            with jax.ensure_compile_time_eval():
                f00 = int(product_fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        acc = acc - f00 * pad
    return acc


def _exact_int_matmul(a8: Array, b8: Array) -> Array:
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


class _SubstrateBase:
    """Shared float-dot (quantization boundary) + batched-conv plumbing."""

    meta: SubstrateMeta

    # -- integer domain ------------------------------------------------------

    def scalar(self, a: Array, b: Array) -> Array:
        raise NotImplementedError

    def dot_int8(self, a8: Array, b8: Array) -> Array:
        raise NotImplementedError

    def _stor(self, x: Array) -> Array:
        """Cast integer operands to the width's storage dtype (int8/int16)."""
        return jnp.asarray(x, quant.storage_dtype(self.meta.width))

    # -- float domain (int-N quantization boundary) ---------------------------

    def dot(self, x: Array, w: Array) -> Array:
        """``x @ w`` with this substrate as the scalar-product unit.

        x: (..., K) activations (any float dtype); w: (K, N) weights.
        Activations use a per-tensor dynamic scale; weights per-output-channel.
        Quantization width follows ``meta.width``. Returns x's dtype.
        """
        bits = self.meta.width
        batch_shape = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape(-1, k)
        qx = quant.quantize(x2, axes=None, bits=bits)   # per-tensor scalar scale
        qw = quant.quantize(w, axes=(0,), bits=bits)    # per-output-channel (1, N)
        acc = self.dot_int8(qx.values, qw.values)
        out = acc.astype(jnp.float32) * (qx.scale * qw.scale)
        return out.reshape(*batch_shape, w.shape[-1]).astype(x.dtype)

    # -- convolution ---------------------------------------------------------

    def conv2d(self, imgs: Array, kernel: Array) -> Array:
        """Batched 'same' integer conv (im2col + ``dot_int8``); see nn.conv."""
        from repro.nn import conv  # late import: conv consumes substrates

        return conv.conv2d_batched(imgs, kernel, self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.meta.spec}>"


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def _reject_wiring(backend: str, mult_name: str | None) -> None:
    """Exact backends take no multiplier wiring — a suffix is a confused
    spec (e.g. ``"int8:design_du2022"`` meaning approx_*), not a no-op."""
    if mult_name not in (None, "exact"):
        raise ValueError(
            f"{backend} is an exact backend and takes no multiplier wiring "
            f"(got {mult_name!r}); use approx_bitexact/approx_lut/approx_stat "
            "to select a wiring.")


def _split_suffix(mult_name: str | None) -> tuple[str, int]:
    """Wiring suffix (possibly carrying ``@N``) → (base_name, width).

    An empty wiring name in front of a width (``"@4"``) is rejected, not
    defaulted: a config typo that drops the wiring but keeps ``@N`` would
    otherwise silently run the proposed design instead of the intended one.
    """
    base, n = mult.split_width(mult_name or "proposed")
    if not base:
        raise ValueError(
            f"malformed multiplier suffix {mult_name!r}: a width needs a "
            "wiring name (mult_name[@N]), e.g. 'proposed@4'")
    return base, n


class ExactSubstrate(_SubstrateBase):
    """Float reference: plain dot in the compute dtype, exact int contraction."""

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("exact", mult_name)
        self.meta = SubstrateMeta("exact", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int8(self, a8, b8):
        return _exact_int_matmul(self._stor(a8), self._stor(b8))

    def dot(self, x, w):
        return jnp.dot(x, w.astype(x.dtype))


class Int8Substrate(_SubstrateBase):
    """Symmetric int8 quantization boundary, exact int32 matmul."""

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("int8", mult_name)
        self.meta = SubstrateMeta("int8", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int8(self, a8, b8):
        return _exact_int_matmul(self._stor(a8), self._stor(b8))


class BitexactSubstrate(_SubstrateBase):
    """Every scalar product through the closed-form multiplier model.

    Supports any wiring at any width 3..16 (``"proposed@16"`` etc.)."""

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        _, self._fn, n = mult.resolve_multiplier(base, n)
        with jax.ensure_compile_time_eval():
            self._f00 = int(self._fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        self.meta = SubstrateMeta("approx_bitexact", base, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="scalar-emulation", width=n)

    def scalar(self, a, b):
        return self._fn(a, b)

    def dot_int8(self, a8, b8):
        return _bitexact_contract(self._stor(a8), self._stor(b8), self._fn,
                                  f00=self._f00)


class LutSubstrate(_SubstrateBase):
    """Gather-based contraction through the (2^N)² product LUT (N ≤ 8)."""

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                f"approx_lut needs an enumerable product table (width <= "
                f"{lut_lib.MAX_LUT_BITS}, got {n}); use approx_bitexact for "
                "wider operands")
        self._key = key
        self.meta = SubstrateMeta("approx_lut", base, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="gather", width=n)

    def _table(self) -> Array:
        return jnp.asarray(lut_lib.build_lut(self._key))

    def scalar(self, a, b):
        return lut_lib.lut_multiply(a, b, self._table())

    def dot_int8(self, a8, b8):
        table = self._table()
        n = self.meta.width
        size, off = 1 << n, 1 << (n - 1)
        return _bitexact_contract(
            self._stor(a8), self._stor(b8),
            lambda x, y: table[(x + off) & (size - 1), (y + off) & (size - 1)],
            f00=lut_lib.f00(self._key))


class StatSubstrate(_SubstrateBase):
    """Exact int32 matmul + separable statistical error model.

    E[e(a,b)] ≈ r[a] + c[b] − µ, where e is the multiplier's error LUT and
    r/c its row/column means. Adds two gathers + two rank-1 terms, lowers to
    MXU-friendly HLO, and is the deployment-scale stand-in used by the
    multi-pod dry-runs (the Pallas kernel replaces it on real hardware).
    Beyond-paper contribution. The correction is defined at contraction level
    (``scalar_faithful=False``): ``dot_int8`` rounds the summed correction
    once per output element, while ``scalar`` rounds per product. Widths ≤ 8
    (the separable model is fit on the exhaustive error LUT).
    """

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                "approx_stat fits its separable error model on the "
                f"exhaustive error LUT (width <= {lut_lib.MAX_LUT_BITS}, "
                f"got {n}); use approx_bitexact for wider operands")
        self._key = key
        self.meta = SubstrateMeta("approx_stat", base, bit_exact=False,
                                  scalar_faithful=False, preferred_backend="any",
                                  cost_hint="mxu", width=n)

    def scalar(self, a, b):
        n = self.meta.width
        off = 1 << (n - 1)
        r, c, _mu = _stat_tables(self._key)
        a = mult.wrap_operand(jnp.asarray(a, jnp.int32), n)
        b = mult.wrap_operand(jnp.asarray(b, jnp.int32), n)
        corr = jnp.asarray(r)[a + off] + jnp.asarray(c)[b + off]
        return a * b + corr.astype(jnp.int32)

    def dot_int8(self, a8, b8):
        n = self.meta.width
        off = 1 << (n - 1)
        # wrap into the width's operand domain first (module contract) so
        # both the exact matmul and the correction gathers see the same
        # operands the scalar model does
        aw = mult.wrap_operand(jnp.asarray(a8, jnp.int32), n)
        bw = mult.wrap_operand(jnp.asarray(b8, jnp.int32), n)
        # wrapped values fit the storage dtype (width ≤ 8 here), so the
        # contraction keeps the int8 MXU path
        exact = _exact_int_matmul(self._stor(aw), self._stor(bw))
        r, c, _mu = _stat_tables(self._key)
        ra = jnp.asarray(r)[aw + off].sum(axis=1)  # (m,)
        cb = jnp.asarray(c)[bw + off].sum(axis=0)  # (n,)
        corr = ra[:, None] + cb[None, :]
        return exact + corr.astype(jnp.int32)


class PallasSubstrate(_SubstrateBase):
    """Tiled Pallas TPU contraction for any wiring at widths 3..8.

    Two kernels behind one spec family, both bit-identical to
    ``approx_bitexact`` at the same wiring/width and both running in
    interpret mode off-TPU so the code path is testable on CPU:

    * ``proposed``@8 — the closed-form kernel (``kernels/approx_matmul``),
      ~25 VPU integer ops per product (fast path, cost hint ``vpu``);
    * everything else — the LUT-input kernel (``kernels/lut_matmul``): the
      scalar product is one gather into the wiring's flat (2^N · 2^N,)
      product table, VMEM-resident for N ≤ 8 (cost hint ``gather``).

    Widths above ``MAX_LUT_BITS`` are rejected — the LUT kernel needs an
    enumerable product table; use ``approx_bitexact`` for wider operands.
    """

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                "approx_pallas needs an enumerable product table for its "
                f"LUT kernel (width <= {lut_lib.MAX_LUT_BITS}, got {n}); "
                "use approx_bitexact for wider operands")
        self._key = key
        self._closed_form = base == "proposed" and n == mult.N_BITS
        self.meta = SubstrateMeta(
            "approx_pallas", base, bit_exact=True, scalar_faithful=True,
            preferred_backend="tpu",
            cost_hint="vpu" if self._closed_form else "gather", width=n)

    def _table(self) -> Array:
        return jnp.asarray(lut_lib.flat_lut(self._key))

    def scalar(self, a, b):
        if self._closed_form:
            from repro.kernels.closed_form import approx_product_i32

            return approx_product_i32(a, b)
        return lut_lib.lut_multiply(
            a, b, jnp.asarray(lut_lib.build_lut(self._key)))

    def dot_int8(self, a8, b8):
        a8 = jnp.asarray(a8, jnp.int32)
        b8 = jnp.asarray(b8, jnp.int32)
        if self._closed_form:
            from repro.kernels.approx_matmul.ops import approx_matmul

            return approx_matmul(a8, b8)
        from repro.kernels.lut_matmul.ops import lut_matmul

        return lut_matmul(a8, b8, self._table())


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[str], ProductSubstrate]] = {}


def register_substrate(name: str,
                       factory: Callable[..., ProductSubstrate]) -> None:
    """Register a backend under ``name``; factory takes a mult suffix (or
    ``None`` when the spec carried no wiring — each backend applies its own
    default or rejects)."""
    _FACTORIES[name] = factory


def list_substrates() -> list[str]:
    """Registered backend names (stable order)."""
    return sorted(_FACTORIES)


class SpecParts(NamedTuple):
    """Parsed ``"backend[:mult_name[@N]]"`` spec string."""

    backend: str
    mult_name: str
    width: int


def _split_spec(spec: str) -> tuple[str, str | None]:
    """Validated ``"backend[:mult_name[@N]]"`` split → (backend, suffix).

    Rejects malformed specs instead of silently normalizing them: an empty
    backend or wiring suffix (``"exact:"``, ``":proposed"``) and any
    whitespace (``"approx_pallas:proposed@8 "``) are grammar errors — a
    stray character in a config would otherwise parse as a different,
    well-formed spec.
    """
    s = str(spec)
    if not s or any(c.isspace() for c in s):
        raise ValueError(
            f"malformed substrate spec {spec!r}: specs follow "
            "backend[:mult_name[@N]] with no whitespace")
    name, sep, suffix = s.partition(":")
    if not name or (sep and not suffix):
        part = "backend" if not name else "wiring suffix"
        raise ValueError(
            f"malformed substrate spec {spec!r}: empty {part} — specs "
            "follow backend[:mult_name[@N]]")
    return name, (suffix if sep else None)


def parse_spec(spec: str) -> SpecParts:
    """``"backend[:mult_name[@N]]"`` → (backend, mult_name, width).

    A missing wiring reads as ``"proposed"`` (the approx backends' default;
    exact backends take no wiring at all); a missing width as 8. Malformed
    specs (empty parts — including an empty wiring name before ``@N`` —
    and whitespace) raise ``ValueError``.
    """
    name, suffix = _split_spec(spec)
    base, width = mult.split_width(suffix or "proposed")
    if not base:
        raise ValueError(
            f"malformed substrate spec {spec!r}: empty wiring name before "
            "'@' — specs follow backend[:mult_name[@N]]")
    return SpecParts(name, base, width)


@functools.lru_cache(maxsize=None)
def get_substrate(spec: str = "exact",
                  mult_name: str | None = None) -> ProductSubstrate:
    """Resolve a spec string to a (cached) substrate instance.

    ``spec`` may carry a wiring+width suffix (``"approx_lut:design_du2022"``,
    ``"approx_bitexact:proposed@16"``); an explicit ``mult_name`` argument
    (which may itself carry ``@N``) overrides the suffix. Backends validate
    the wiring and width: approx backends default a missing wiring to
    ``"proposed"`` at width 8, exact backends reject any suffix outright.
    """
    name, suffix = _split_spec(spec)
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown product substrate: {name!r} (known: {list_substrates()})")
    return _FACTORIES[name](mult_name or suffix or None)


def as_substrate(s: "str | ProductSubstrate") -> ProductSubstrate:
    """Accept either a spec string or an already-resolved substrate."""
    if isinstance(s, str):
        return get_substrate(s)
    return s


register_substrate("exact", ExactSubstrate)
register_substrate("int8", Int8Substrate)
register_substrate("approx_bitexact", BitexactSubstrate)
register_substrate("approx_lut", LutSubstrate)
register_substrate("approx_stat", StatSubstrate)
register_substrate("approx_pallas", PallasSubstrate)
