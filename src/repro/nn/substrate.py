"""Product-substrate layer: one registry for every scalar-product unit.

The paper's thesis is that a single scalar-product unit — the sign-focused-
compressor approximate multiplier — can be swapped underneath convolution and
matmul workloads. This module makes that swap a first-class object instead of
stringly-typed ``if mode == ...`` chains: a :class:`ProductSubstrate` bundles
the three contraction capabilities every workload needs

* ``scalar(a, b)``   — the raw int8×int8→int32 product model,
* ``dot_int8(a, b)`` — integer-domain (M,K)@(K,N) contraction (exact adder),
* ``dot(x, w)``      — float-domain matmul through the int8 quantization
                       boundary (per-tensor activations, per-channel weights),
* ``conv2d(imgs,k)`` — batched NHW(C) 'same' convolution via im2col + dot,

plus :class:`SubstrateMeta` (bit-exactness, preferred backend, cost hints)
so launchers/benchmarks can reason about a substrate without running it.

Registered backends (``list_substrates()``):

* ``exact``           — float reference dot; exact integer contraction.
* ``int8``            — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact`` — every scalar product through the closed-form
                        multiplier model; bit-identical to the netlist.
* ``approx_lut``      — same contraction through the 256×256 product LUT.
* ``approx_stat``     — exact int32 matmul + separable statistical error
                        model (MXU-friendly deployment stand-in).
* ``approx_pallas``   — the tiled Pallas TPU kernel
                        (``kernels/approx_matmul``), interpret-mode fallback
                        off-TPU; bit-identical to ``approx_bitexact``.

Spec strings select a backend and a multiplier wiring at once:
``"approx_lut:design_du2022"`` — any name in
``core.multiplier.ALL_MULTIPLIERS`` is reachable. A bare backend name
defaults to the paper's ``proposed`` wiring.

NOTE: the approximate multiplier maps (0,0) → +192 (compensation constant
fires regardless of operands — true to the netlist), so zero padding of the
contraction dimension injects spurious contributions; every backend corrects
for f(0,0) where it pads.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.nn import quant

Array = jnp.ndarray

_K_CHUNK = 16  # k-slab size for the bit-exact contraction


# ---------------------------------------------------------------------------
# Protocol + metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubstrateMeta:
    """Static facts about a substrate, for dispatch-free reasoning.

    bit_exact:        product values are bit-identical to the hardware netlist
                      (exact backends are trivially bit-exact to *their* model).
    scalar_faithful:  ``dot_int8(a, b) == Σ_k scalar(a_k, b_k)`` exactly —
                      holds for everything except the statistical error model,
                      which is defined at contraction level (one rounding of
                      the separable correction per output element).
    preferred_backend: "tpu" for kernels that only pay off on real hardware,
                      "any" otherwise.
    cost_hint:        dominant execution resource: "mxu" | "vpu" | "gather" |
                      "scalar-emulation".
    """

    name: str
    mult_name: str
    bit_exact: bool
    scalar_faithful: bool
    preferred_backend: str
    cost_hint: str

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.mult_name}"

    @property
    def label(self) -> str:
        """Short display name: bare backend for default wirings, full spec
        otherwise (keeps benchmark row names distinct across wirings)."""
        return self.name if self.mult_name in ("exact", "proposed") else self.spec


@runtime_checkable
class ProductSubstrate(Protocol):
    """Anything with the four contraction capabilities + metadata."""

    meta: SubstrateMeta

    def scalar(self, a: Array, b: Array) -> Array: ...

    def dot_int8(self, a8: Array, b8: Array) -> Array: ...

    def dot(self, x: Array, w: Array) -> Array: ...

    def conv2d(self, imgs: Array, kernel: Array) -> Array: ...


# ---------------------------------------------------------------------------
# Shared contraction machinery
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _stat_tables(mult_name: str) -> tuple[np.ndarray, np.ndarray, float]:
    """Separable error model (r[a], c[b], µ) from the error LUT."""
    e = lut_lib.error_lut(mult_name).astype(np.float64)
    mu = e.mean()
    r = e.mean(axis=1) - 0.5 * mu
    c = e.mean(axis=0) - 0.5 * mu
    return r.astype(np.float32), c.astype(np.float32), float(mu)


def _bitexact_contract(a8: Array, b8: Array, product_fn,
                       f00: int | None = None) -> Array:
    """sum_k f(a[m,k], b[k,n]) with f an arbitrary int8×int8→int32 model.

    ``f00``: the model's f(0,0) value, needed to correct k-padding. Callers
    that know it statically pass it so the contraction stays traceable (the
    serving path jits whole ``edge_detect_batched`` calls through here);
    when omitted it is constant-folded out of the trace.
    """
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2, (a8.shape, b8.shape)
    pad = (-k) % _K_CHUNK
    if pad:
        # pad with zeros, then subtract the spurious f(0,0)=192 contributions
        a8 = jnp.pad(a8, ((0, 0), (0, pad)))
        b8 = jnp.pad(b8, ((0, pad), (0, 0)))
    steps = a8.shape[1] // _K_CHUNK
    a3 = a8.reshape(m, steps, _K_CHUNK).transpose(1, 0, 2).astype(jnp.int32)
    b3 = b8.reshape(steps, _K_CHUNK, n).astype(jnp.int32)

    def body(acc, slabs):
        a_c, b_c = slabs  # (m, ck), (ck, n)
        prod = product_fn(a_c[:, :, None], b_c[None, :, :])  # (m, ck, n)
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (a3, b3))
    if pad:
        if f00 is None:
            with jax.ensure_compile_time_eval():
                f00 = int(product_fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        acc = acc - f00 * pad
    return acc


def _exact_int_matmul(a8: Array, b8: Array) -> Array:
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


class _SubstrateBase:
    """Shared float-dot (quantization boundary) + batched-conv plumbing."""

    meta: SubstrateMeta

    # -- integer domain ------------------------------------------------------

    def scalar(self, a: Array, b: Array) -> Array:
        raise NotImplementedError

    def dot_int8(self, a8: Array, b8: Array) -> Array:
        raise NotImplementedError

    # -- float domain (int8 quantization boundary) ---------------------------

    def dot(self, x: Array, w: Array) -> Array:
        """``x @ w`` with this substrate as the scalar-product unit.

        x: (..., K) activations (any float dtype); w: (K, N) weights.
        Activations use a per-tensor dynamic scale; weights per-output-channel.
        Returns the result in x's dtype.
        """
        batch_shape = x.shape[:-1]
        k = x.shape[-1]
        x2 = x.reshape(-1, k)
        qx = quant.quantize(x2, axes=None)           # per-tensor scalar scale
        qw = quant.quantize(w, axes=(0,))            # per-output-channel (1, N)
        acc = self.dot_int8(qx.values, qw.values)
        out = acc.astype(jnp.float32) * (qx.scale * qw.scale)
        return out.reshape(*batch_shape, w.shape[-1]).astype(x.dtype)

    # -- convolution ---------------------------------------------------------

    def conv2d(self, imgs: Array, kernel: Array) -> Array:
        """Batched 'same' integer conv (im2col + ``dot_int8``); see nn.conv."""
        from repro.nn import conv  # late import: conv consumes substrates

        return conv.conv2d_batched(imgs, kernel, self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.meta.spec}>"


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def _reject_wiring(backend: str, mult_name: str | None) -> None:
    """Exact backends take no multiplier wiring — a suffix is a confused
    spec (e.g. ``"int8:design_du2022"`` meaning approx_*), not a no-op."""
    if mult_name not in (None, "exact"):
        raise ValueError(
            f"{backend} is an exact backend and takes no multiplier wiring "
            f"(got {mult_name!r}); use approx_bitexact/approx_lut/approx_stat "
            "to select a wiring.")


class ExactSubstrate(_SubstrateBase):
    """Float reference: plain dot in the compute dtype, exact int contraction."""

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("exact", mult_name)
        self.meta = SubstrateMeta("exact", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int8(self, a8, b8):
        return _exact_int_matmul(jnp.asarray(a8, jnp.int8),
                                 jnp.asarray(b8, jnp.int8))

    def dot(self, x, w):
        return jnp.dot(x, w.astype(x.dtype))


class Int8Substrate(_SubstrateBase):
    """Symmetric int8 quantization boundary, exact int32 matmul."""

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("int8", mult_name)
        self.meta = SubstrateMeta("int8", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int8(self, a8, b8):
        return _exact_int_matmul(jnp.asarray(a8, jnp.int8),
                                 jnp.asarray(b8, jnp.int8))


class BitexactSubstrate(_SubstrateBase):
    """Every scalar product through the closed-form multiplier model."""

    def __init__(self, mult_name: str | None = None):
        mult_name = mult_name or "proposed"
        if mult_name not in mult.ALL_MULTIPLIERS:
            raise ValueError(f"unknown multiplier wiring: {mult_name!r}")
        self._fn = mult.ALL_MULTIPLIERS[mult_name]
        with jax.ensure_compile_time_eval():
            self._f00 = int(self._fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        self.meta = SubstrateMeta("approx_bitexact", mult_name, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="scalar-emulation")

    def scalar(self, a, b):
        return self._fn(a, b)

    def dot_int8(self, a8, b8):
        return _bitexact_contract(jnp.asarray(a8, jnp.int8),
                                  jnp.asarray(b8, jnp.int8), self._fn,
                                  f00=self._f00)


class LutSubstrate(_SubstrateBase):
    """Gather-based contraction through the 256×256 product LUT."""

    def __init__(self, mult_name: str | None = None):
        mult_name = mult_name or "proposed"
        if mult_name not in mult.ALL_MULTIPLIERS:
            raise ValueError(f"unknown multiplier wiring: {mult_name!r}")
        self.meta = SubstrateMeta("approx_lut", mult_name, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="gather")

    def _table(self) -> Array:
        return jnp.asarray(lut_lib.build_lut(self.meta.mult_name))

    def scalar(self, a, b):
        return lut_lib.lut_multiply(a, b, self._table())

    def dot_int8(self, a8, b8):
        table = self._table()
        f00 = int(lut_lib.build_lut(self.meta.mult_name)[128, 128])
        return _bitexact_contract(jnp.asarray(a8, jnp.int8),
                                  jnp.asarray(b8, jnp.int8),
                                  lambda x, y: table[x + 128, y + 128],
                                  f00=f00)


class StatSubstrate(_SubstrateBase):
    """Exact int32 matmul + separable statistical error model.

    E[e(a,b)] ≈ r[a] + c[b] − µ, where e is the multiplier's error LUT and
    r/c its row/column means. Adds two gathers + two rank-1 terms, lowers to
    MXU-friendly HLO, and is the deployment-scale stand-in used by the
    multi-pod dry-runs (the Pallas kernel replaces it on real hardware).
    Beyond-paper contribution. The correction is defined at contraction level
    (``scalar_faithful=False``): ``dot_int8`` rounds the summed correction
    once per output element, while ``scalar`` rounds per product.
    """

    def __init__(self, mult_name: str | None = None):
        mult_name = mult_name or "proposed"
        if mult_name not in mult.ALL_MULTIPLIERS:
            raise ValueError(f"unknown multiplier wiring: {mult_name!r}")
        self.meta = SubstrateMeta("approx_stat", mult_name, bit_exact=False,
                                  scalar_faithful=False, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        r, c, _mu = _stat_tables(self.meta.mult_name)
        a = jnp.asarray(a, jnp.int32)
        b = jnp.asarray(b, jnp.int32)
        corr = jnp.asarray(r)[a + 128] + jnp.asarray(c)[b + 128]
        return a * b + corr.astype(jnp.int32)

    def dot_int8(self, a8, b8):
        a8 = jnp.asarray(a8, jnp.int8)
        b8 = jnp.asarray(b8, jnp.int8)
        exact = _exact_int_matmul(a8, b8)
        r, c, _mu = _stat_tables(self.meta.mult_name)
        ra = jnp.asarray(r)[a8.astype(jnp.int32) + 128].sum(axis=1)  # (m,)
        cb = jnp.asarray(c)[b8.astype(jnp.int32) + 128].sum(axis=0)  # (n,)
        corr = ra[:, None] + cb[None, :]
        return exact + corr.astype(jnp.int32)


class PallasSubstrate(_SubstrateBase):
    """The tiled Pallas TPU kernel (``kernels/approx_matmul``).

    Bit-identical to ``approx_bitexact`` for the proposed wiring (the kernel
    hard-codes the proposed closed form); runs in interpret mode off-TPU so
    the same code path is testable on CPU.
    """

    def __init__(self, mult_name: str | None = None):
        mult_name = mult_name or "proposed"
        if mult_name != "proposed":
            raise ValueError(
                "approx_pallas hard-codes the proposed closed form "
                f"(kernels/closed_form.py); got mult_name={mult_name!r}. "
                "Use approx_lut / approx_bitexact for other wirings.")
        self.meta = SubstrateMeta("approx_pallas", mult_name, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="tpu",
                                  cost_hint="vpu")

    def scalar(self, a, b):
        from repro.kernels.closed_form import approx_product_i32

        return approx_product_i32(a, b)

    def dot_int8(self, a8, b8):
        from repro.kernels.approx_matmul.ops import approx_matmul

        return approx_matmul(jnp.asarray(a8, jnp.int32),
                             jnp.asarray(b8, jnp.int32))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[str], ProductSubstrate]] = {}


def register_substrate(name: str,
                       factory: Callable[..., ProductSubstrate]) -> None:
    """Register a backend under ``name``; factory takes a mult_name (or
    ``None`` when the spec carried no wiring — each backend applies its own
    default or rejects)."""
    _FACTORIES[name] = factory


def list_substrates() -> list[str]:
    """Registered backend names (stable order)."""
    return sorted(_FACTORIES)


def parse_spec(spec: str) -> tuple[str, str]:
    """``"backend[:mult_name]"`` → (backend, mult_name).

    A missing wiring reads as ``"proposed"`` (the approx backends' default;
    exact backends take no wiring at all).
    """
    name, _, suffix = str(spec).partition(":")
    return name, suffix or "proposed"


@functools.lru_cache(maxsize=None)
def get_substrate(spec: str = "exact",
                  mult_name: str | None = None) -> ProductSubstrate:
    """Resolve a spec string to a (cached) substrate instance.

    ``spec`` may carry a wiring suffix (``"approx_lut:design_du2022"``); an
    explicit ``mult_name`` argument overrides the suffix. Backends validate
    the wiring: approx backends default a missing one to ``"proposed"``,
    exact backends reject any wiring outright.
    """
    name, _, suffix = str(spec).partition(":")
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown product substrate: {name!r} (known: {list_substrates()})")
    return _FACTORIES[name](mult_name or suffix or None)


def as_substrate(s: "str | ProductSubstrate") -> ProductSubstrate:
    """Accept either a spec string or an already-resolved substrate."""
    if isinstance(s, str):
        return get_substrate(s)
    return s


register_substrate("exact", ExactSubstrate)
register_substrate("int8", Int8Substrate)
register_substrate("approx_bitexact", BitexactSubstrate)
register_substrate("approx_lut", LutSubstrate)
register_substrate("approx_stat", StatSubstrate)
register_substrate("approx_pallas", PallasSubstrate)
