"""Product-substrate layer: one registry for every scalar-product unit.

The paper's thesis is that a single scalar-product unit — the sign-focused-
compressor approximate multiplier — can be swapped underneath convolution and
matmul workloads. This module makes that swap a first-class object instead of
stringly-typed ``if mode == ...`` chains: a :class:`ProductSubstrate` bundles
one ``dot_general``-style contraction entry point

* ``dot_general(x, w, spec)`` — the single contraction surface. A
  :class:`ContractionSpec` carries (i) jax-style *dimension numbers*
  (batched/transposed contractions without hand reshapes), (ii) an optional
  :class:`QuantPolicy` (the float→intN quantization boundary: per-tensor vs
  per-channel scales, width, pinned scales), and (iii) an optional
  :class:`Partitioning` (mesh + axis names) that lowers the contraction
  through ``shard_map`` — data-parallel M, reduce-scattered K — while
  staying bit-identical to the unsharded path for every bit-exact backend,

plus the raw product model and thin compatibility wrappers

* ``scalar(a, b)``   — the raw intN×intN→int32 product model,
* ``dot_int(a, b)``  — 2-D integer-domain (M,K)@(K,N) contraction (exact
                       adder; operands are int8 for widths ≤ 8, int16 wider),
* ``dot_int8``       — deprecated alias of ``dot_int`` (the name was a lie
                       at N=16),
* ``dot(x, w)``      — deprecated wrapper: ``dot_general`` with the default
                       matmul dims + default ``QuantPolicy``,
* ``conv2d(imgs,k)`` — batched NHW(C) 'same' convolution via im2col +
                       ``dot_general``,

and :class:`SubstrateMeta` (bit-exactness, operand width, preferred
backend, cost hints) so launchers/benchmarks can reason about a substrate
without running it.

Registered backends (``list_substrates()``):

* ``exact``           — float reference dot; exact integer contraction.
* ``int8``            — symmetric int8 quantization, exact int32 matmul.
* ``approx_bitexact`` — every scalar product through the closed-form
                        multiplier model; bit-identical to the netlist.
                        Any width 3..16.
* ``approx_lut``      — same contraction through the (2^N)² product LUT.
                        Widths ≤ 8 (the table must be enumerable).
* ``approx_stat``     — exact int32 matmul + separable statistical error
                        model (MXU-friendly deployment stand-in). Widths ≤ 8
                        (the model is fit on the exhaustive error LUT).
* ``approx_pallas``   — the tiled Pallas TPU kernels, interpret-mode
                        fallback off-TPU; bit-identical to
                        ``approx_bitexact``. Any wiring at widths 3..8:
                        CSP wirings run a *generated* closed-form VPU
                        kernel (``kernels.closed_form.make_closed_form``
                        through ``kernels/approx_matmul``); non-CSP product
                        models (``"exact"``) fall back to the LUT-input
                        kernel (``kernels/lut_matmul``). Convolutions take
                        the fused in-kernel-im2col path
                        (``kernels/fused_conv``) via ``fused_conv2d``.

Spec grammar — ``"backend[:mult_name[@N]]"`` — selects a backend, a
multiplier wiring, and an operand width at once:

* ``"approx_lut:design_du2022"`` — any name in
  ``core.multiplier.ALL_MULTIPLIERS`` (or a ``csp_*`` alias) is reachable;
* ``"approx_lut:csp_axc1@4"`` / ``"approx_bitexact:proposed@16"`` — the same
  wiring instantiated at 4- or 16-bit operand width;
* a bare backend name defaults to the paper's ``proposed`` wiring at N=8.

Width contract: ``meta.width`` is the operand width N. Integer operands
outside the signed N-bit range are **wrapped** (low N bits, sign-extended)
by every approx backend, so bitexact/LUT stay bit-identical on arbitrary
ints; the float path quantizes into range so wrapping never fires.
N=4 and N=8 models are exhaustively verified against the structural netlist
model in tests; N=16 is verified on random samples.

Accumulator contract: every integer contraction accumulates in int32 (JAX
runs without x64 here), i.e. sums are exact until they exceed ±2^31 and
wrap mod 2^32 beyond that. At N ≤ 8 no realistic K overflows; at N=16 the
worst-case product is ~2^30, so keep K·|products| below 2^31 (edge-detection
taps and quantized convs do) — ``scalar_faithful`` parity is defined modulo
2^32. int32 addition is exact and associative under that modulus, which is
why the sharded (psum / psum_scatter) reduction order cannot perturb
bit-exact backends.

NOTE: the approximate multiplier maps (0,0) → +compensation_constant(N)
(the constant fires regardless of operands — true to the netlist; +192 at
N=8), so zero padding of the contraction dimension injects spurious
contributions; every backend corrects for f(0,0) where it pads — including
per K-shard under a :class:`Partitioning`, where each shard corrects its
own local k-chunk padding and the global shard-divisibility pad is
corrected once after the reduce.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Callable, Dict, NamedTuple, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.nn import quant
from repro.obs.meter import current_meter as _current_meter

Array = jnp.ndarray

_K_CHUNK = 16  # k-slab size for the bit-exact contraction


# ---------------------------------------------------------------------------
# Protocol + metadata
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubstrateMeta:
    """Static facts about a substrate, for dispatch-free reasoning.

    bit_exact:        product values are bit-identical to the hardware netlist
                      (exact backends are trivially bit-exact to *their* model).
    scalar_faithful:  ``dot_int(a, b) == Σ_k scalar(a_k, b_k)`` exactly —
                      holds for everything except the statistical error model,
                      which is defined at contraction level (one rounding of
                      the separable correction per output element).
    preferred_backend: "tpu" for kernels that only pay off on real hardware,
                      "any" otherwise.
    cost_hint:        dominant execution resource: "mxu" | "vpu" | "gather" |
                      "scalar-emulation".
    width:            operand width N of the scalar-product unit (bits).
    """

    name: str
    mult_name: str
    bit_exact: bool
    scalar_faithful: bool
    preferred_backend: str
    cost_hint: str
    width: int = mult.N_BITS

    @property
    def mult_key(self) -> str:
        """Wiring + width key, as it appears in spec strings (``@8`` implicit)."""
        if self.width == mult.N_BITS:
            return self.mult_name
        return f"{self.mult_name}@{self.width}"

    @property
    def spec(self) -> str:
        return f"{self.name}:{self.mult_key}"

    @property
    def label(self) -> str:
        """Short display name: bare backend for default wirings at default
        width, full spec otherwise (keeps benchmark row names distinct)."""
        if self.mult_name in ("exact", "proposed") and self.width == mult.N_BITS:
            return self.name
        return self.spec


@runtime_checkable
class ProductSubstrate(Protocol):
    """Anything with the ``dot_general`` contraction surface + metadata.

    ``dot_int8`` / ``dot`` / ``conv2d`` are thin deprecated wrappers kept
    for signature stability — every one routes through ``dot_general``.
    """

    meta: SubstrateMeta

    def scalar(self, a: Array, b: Array) -> Array: ...

    def dot_general(self, x: Array, w: Array,
                    spec: "Optional[ContractionSpec]" = None) -> Array: ...

    def dot_int(self, a: Array, b: Array) -> Array: ...

    def dot_int8(self, a8: Array, b8: Array) -> Array: ...  # deprecated alias

    def dot(self, x: Array, w: Array) -> Array: ...         # deprecated wrapper

    def conv2d(self, imgs: Array, kernel: Array) -> Array: ...


# ---------------------------------------------------------------------------
# Contraction policies: dimension numbers + quantization + partitioning
# ---------------------------------------------------------------------------

#: jax ``dot_general``-style dimension numbers:
#: ``((lhs_contracting, rhs_contracting), (lhs_batch, rhs_batch))``.
#: Negative axes are allowed (normalized per operand rank).
DimensionNumbers = Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]],
                         Tuple[Tuple[int, ...], Tuple[int, ...]]]

#: Plain matmul dims: contract the last lhs axis with the first rhs axis —
#: valid for any lhs rank (the historical ``dot(x, w)`` shape contract).
MATMUL_DIMS: DimensionNumbers = (((-1,), (0,)), ((), ()))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Float→intN quantization boundary policy for ``dot_general``.

    Extracted from the historical ``dot`` so callers can vary (or pin) the
    policy per call site instead of inheriting one hard-coded choice.

    bits:     operand width to quantize to (None → the substrate's
              ``meta.width``; must not exceed it — wider codes would wrap in
              the narrower multiplier).
    x_mode:   activation scale granularity — ``"per_tensor"`` (one dynamic
              scalar scale, the historical default) or ``"per_channel"``
              (one scale per output row, i.e. per flattened lhs free
              element).
    w_mode:   weight scale granularity — ``"per_channel"`` (one scale per
              flattened rhs free element, the historical default) or
              ``"per_tensor"``.
    x_scale / w_scale:
              pinned scales. When set, the dynamic absmax computation is
              skipped and values quantize as ``round(v / scale)`` — this is
              how callers reuse one calibrated scale across many calls.
              Shapes broadcast against the *normalized* operand layouts:
              lhs ``(B, M, 1)`` and rhs ``(B, 1, N)`` (scalar, ``(N,)`` etc.
              all work for the plain-matmul dims).
    eps:      epsilon guard for the dynamic scale: ``scale =
              max(absmax, eps) / qmax``. Keeps all-zero operand tensors
              from producing a 0/0 scale — a zero tensor quantizes to
              zeros under a tiny-but-finite scale, so downstream output is
              exactly representable zero, not NaN.
    """

    bits: Optional[int] = None
    x_mode: str = "per_tensor"
    w_mode: str = "per_channel"
    x_scale: Optional[Array] = None
    w_scale: Optional[Array] = None
    eps: float = 1e-8

    def __post_init__(self):
        for field_name, mode in (("x_mode", self.x_mode),
                                 ("w_mode", self.w_mode)):
            if mode not in ("per_tensor", "per_channel"):
                raise ValueError(
                    f"QuantPolicy.{field_name} must be 'per_tensor' or "
                    f"'per_channel', got {mode!r}")
        if self.bits is not None and not (2 <= self.bits <= 16):
            raise ValueError(
                f"QuantPolicy.bits must be in [2, 16], got {self.bits}")
        if self.eps <= 0:
            raise ValueError(f"QuantPolicy.eps must be > 0, got {self.eps}")


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """Mesh lowering policy: shard the contraction through ``shard_map``.

    m_axis: mesh axis carrying data-parallel output rows (the flattened lhs
            free dims). Rows pad up to the axis size and crop after.
    k_axis: mesh axis the contraction dim is reduce-scattered over. Each
            shard contracts its K slice locally (every backend's own
            per-shard f(0,0) k-padding correction applies *inside* the
            shard), then partial sums combine with an int32 psum_scatter
            (psum when N doesn't divide the axis). int32 addition is exact,
            so bit-exact backends stay bit-identical to the unsharded path
            regardless of reduction order. When K doesn't divide the axis
            size, the global zero-pad is corrected once with the wiring's
            f(0,0) after the reduce — only possible for scalar-faithful
            substrates (``approx_stat`` requires divisible K).

    ``approx_stat`` caveat: its separable correction rounds once per shard
    instead of once globally, so sharded results may differ from unsharded
    by the per-shard truncation (the backend is not bit_exact to begin
    with).
    """

    mesh: jax.sharding.Mesh
    m_axis: Optional[str] = "data"
    k_axis: Optional[str] = None

    def __post_init__(self):
        if self.m_axis is None and self.k_axis is None:
            raise ValueError(
                "Partitioning needs at least one of m_axis / k_axis")
        for ax in (self.m_axis, self.k_axis):
            if ax is not None and ax not in self.mesh.axis_names:
                raise ValueError(
                    f"Partitioning axis {ax!r} is not a mesh axis "
                    f"(mesh has {self.mesh.axis_names})")
        if self.m_axis is not None and self.m_axis == self.k_axis:
            raise ValueError(
                f"m_axis and k_axis must differ, both are {self.m_axis!r}")

    @property
    def m_shards(self) -> int:
        return int(self.mesh.shape[self.m_axis]) if self.m_axis else 1

    @property
    def k_shards(self) -> int:
        return int(self.mesh.shape[self.k_axis]) if self.k_axis else 1


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    """Everything ``dot_general`` needs beyond the two operands.

    dimension_numbers: jax ``dot_general`` style (negative axes allowed).
                       Output layout matches ``jax.lax.dot_general``:
                       ``(batch..., lhs_free..., rhs_free...)``.
    quant:             None → integer-domain contraction (operands must be
                       integers); a :class:`QuantPolicy` → float operands
                       through the quantization boundary.
    partitioning:      None → single-device contraction; a
                       :class:`Partitioning` → lowered through shard_map.
    site:              optional contraction-site name (``"layer.3.attn.wq"``,
                       ``"conv.edge.center"`` — see :mod:`repro.nn.plan`);
                       purely observational: the telemetry meter attributes
                       MAC/energy counts to it instead of the shape label.
    """

    dimension_numbers: DimensionNumbers = MATMUL_DIMS
    quant: Optional[QuantPolicy] = None
    partitioning: Optional[Partitioning] = None
    site: Optional[str] = None

    @staticmethod
    def matmul(quant: Optional[QuantPolicy] = None,
               partitioning: Optional[Partitioning] = None,
               site: Optional[str] = None) -> "ContractionSpec":
        """Plain ``(…, K) @ (K, N)`` spec (the historical ``dot`` shape)."""
        return ContractionSpec(MATMUL_DIMS, quant, partitioning, site)


# -- ambient partitioning (opt-in mesh lowering for deep call sites) --------

_PART_STATE = threading.local()


def current_partitioning() -> Optional[Partitioning]:
    """The ambient :class:`Partitioning` installed by
    :func:`partitioning_scope`, or None. Read at *trace* time by call sites
    that cannot thread a spec explicitly (``models.common.dense``)."""
    return getattr(_PART_STATE, "value", None)


@contextlib.contextmanager
def partitioning_scope(p: Optional[Partitioning]):
    """Install an ambient Partitioning for the duration of the block.

    Used by the launch layer (``repro.launch.dryrun --dot-partition``) to
    lower every model ``dense`` contraction through shard_map without
    threading a spec through the whole model zoo. ``None`` is a no-op scope.
    """
    prev = getattr(_PART_STATE, "value", None)
    _PART_STATE.value = p
    try:
        yield p
    finally:
        _PART_STATE.value = prev


# -- ambient contraction override (the QAT layer's injection point) ---------

_DOT_OVERRIDE_STATE = threading.local()


def current_dot_override():
    """The ambient contraction override installed by
    :func:`dot_override_scope`, or None. Read at *trace* time by call sites
    that route through the ambient plan (``models.common.dense``)."""
    return getattr(_DOT_OVERRIDE_STATE, "value", None)


@contextlib.contextmanager
def dot_override_scope(fn):
    """Install an ambient contraction override for the duration of the block.

    ``fn(spec_str, x, w, cspec) -> Array`` replaces the default
    ``get_substrate(spec_str).dot_general(x, w, cspec)`` at every consulting
    call site. The hook exists so higher layers can change *how* a resolved
    (site → spec) assignment contracts without the nn layer importing them —
    ``repro.train.qat.qat_scope`` installs its straight-through-estimator
    wrapper here, keeping forward values bit-identical to the substrate
    while making the contraction differentiable. ``None`` is a no-op scope.
    Thread-local, like :func:`partitioning_scope`.
    """
    prev = getattr(_DOT_OVERRIDE_STATE, "value", None)
    _DOT_OVERRIDE_STATE.value = fn
    try:
        yield fn
    finally:
        _DOT_OVERRIDE_STATE.value = prev


# ---------------------------------------------------------------------------
# Dimension-number normalization + contraction planning
# ---------------------------------------------------------------------------


def _norm_axes(axes, ndim: int, what: str) -> Tuple[int, ...]:
    out = []
    for d in axes:
        d = int(d)
        if not -ndim <= d < ndim:
            raise ValueError(
                f"{what} dimension {d} out of range for rank-{ndim} operand")
        out.append(d % ndim)
    if len(set(out)) != len(out):
        raise ValueError(f"duplicate {what} dimensions: {tuple(axes)}")
    return tuple(out)


class _Plan(NamedTuple):
    """Precomputed transposes/reshapes taking arbitrary dimension numbers to
    the canonical batched 2-D form ``(B, M, K) @ (B, K, N) -> (B, M, N)``."""

    dims: DimensionNumbers          # normalized (non-negative) numbers
    lhs_perm: Tuple[int, ...]
    rhs_perm: Tuple[int, ...]
    b: int
    m: int
    k: int
    n: int
    out_shape: Tuple[int, ...]

    def lhs3(self, x: Array) -> Array:
        return x.transpose(self.lhs_perm).reshape(self.b, self.m, self.k)

    def rhs3(self, w: Array) -> Array:
        return w.transpose(self.rhs_perm).reshape(self.b, self.k, self.n)

    def unflatten(self, out3: Array) -> Array:
        return out3.reshape(self.out_shape)


def _plan_contraction(lhs_shape, rhs_shape,
                      dimension_numbers: DimensionNumbers) -> _Plan:
    try:
        (lc, rc), (lb, rb) = dimension_numbers
    except (TypeError, ValueError) as e:
        raise ValueError(
            "dimension_numbers must be ((lhs_contracting, rhs_contracting), "
            f"(lhs_batch, rhs_batch)); got {dimension_numbers!r}") from e
    lnd, rnd = len(lhs_shape), len(rhs_shape)
    lc = _norm_axes(lc, lnd, "lhs contracting")
    rc = _norm_axes(rc, rnd, "rhs contracting")
    lb = _norm_axes(lb, lnd, "lhs batch")
    rb = _norm_axes(rb, rnd, "rhs batch")
    if len(lc) != len(rc) or len(lb) != len(rb):
        raise ValueError(
            f"contracting/batch dimension lists must pair up: "
            f"lhs {lc}/{lb} vs rhs {rc}/{rb}")
    if set(lc) & set(lb) or set(rc) & set(rb):
        raise ValueError(
            "a dimension cannot be both contracting and batch: "
            f"lhs {lc}∩{lb}, rhs {rc}∩{rb}")
    for dl, dr in zip(lc, rc):
        if lhs_shape[dl] != rhs_shape[dr]:
            raise ValueError(
                f"contracting dimension mismatch: lhs dim {dl} has size "
                f"{lhs_shape[dl]}, rhs dim {dr} has size {rhs_shape[dr]}")
    for dl, dr in zip(lb, rb):
        if lhs_shape[dl] != rhs_shape[dr]:
            raise ValueError(
                f"batch dimension mismatch: lhs dim {dl} has size "
                f"{lhs_shape[dl]}, rhs dim {dr} has size {rhs_shape[dr]}")
    lfree = tuple(d for d in range(lnd) if d not in lc and d not in lb)
    rfree = tuple(d for d in range(rnd) if d not in rc and d not in rb)
    prod = lambda dims, shape: int(np.prod([shape[d] for d in dims],
                                           dtype=np.int64)) if dims else 1
    out_shape = tuple([lhs_shape[d] for d in lb]
                      + [lhs_shape[d] for d in lfree]
                      + [rhs_shape[d] for d in rfree])
    return _Plan(
        dims=((lc, rc), (lb, rb)),
        lhs_perm=lb + lfree + lc,
        rhs_perm=rb + rc + rfree,
        b=prod(lb, lhs_shape), m=prod(lfree, lhs_shape),
        k=prod(lc, lhs_shape), n=prod(rfree, rhs_shape),
        out_shape=out_shape,
    )


def _quantize_operand(t3: Array, mode: str, pinned_scale, contract_axis: int,
                      bits: int, eps: float):
    """Quantize a normalized ``(B, ·, ·)`` operand per the policy.

    Returns (int values in the width's storage dtype, f32 scale). The
    dynamic branch is ``quant.quantize`` — whose scale is epsilon-guarded:
    an all-zero tensor gets a tiny finite scale, so its quantized values
    and the dequantized output are exactly zero instead of NaN (regression:
    zero image → zero edge map through the float path). A pinned scale
    skips the absmax and quantizes as ``round(v / scale)``.
    """
    if pinned_scale is None:
        axes = None if mode == "per_tensor" else (contract_axis,)
        q = quant.quantize(t3, axes=axes, bits=bits, eps=eps)
        return q.values, q.scale
    qm = quant.qmax(bits)
    scale = jnp.asarray(pinned_scale, jnp.float32)
    q = jnp.clip(jnp.round(t3.astype(jnp.float32) / scale), -qm, qm)
    return q.astype(quant.storage_dtype(bits)), scale


# ---------------------------------------------------------------------------
# Shared contraction machinery
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _stat_tables(mult_key: str) -> tuple[np.ndarray, np.ndarray, float]:
    """Separable error model (r[a], c[b], µ) from the width-N error LUT."""
    e = lut_lib.error_lut(mult_key).astype(np.float64)
    mu = e.mean()
    r = e.mean(axis=1) - 0.5 * mu
    c = e.mean(axis=0) - 0.5 * mu
    return r.astype(np.float32), c.astype(np.float32), float(mu)


def _bitexact_contract(a8: Array, b8: Array, product_fn,
                       f00: int | None = None) -> Array:
    """sum_k f(a[m,k], b[k,n]) with f an arbitrary intN×intN→int32 model.

    ``f00``: the model's f(0,0) value, needed to correct k-padding. Callers
    that know it statically pass it so the contraction stays traceable (the
    serving path jits whole ``edge_detect_batched`` calls through here);
    when omitted it is constant-folded out of the trace.
    """
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2, (a8.shape, b8.shape)
    pad = (-k) % _K_CHUNK
    if pad:
        # pad with zeros, then subtract the spurious f(0,0) contributions
        a8 = jnp.pad(a8, ((0, 0), (0, pad)))
        b8 = jnp.pad(b8, ((0, pad), (0, 0)))
    steps = a8.shape[1] // _K_CHUNK
    a3 = a8.reshape(m, steps, _K_CHUNK).transpose(1, 0, 2).astype(jnp.int32)
    b3 = b8.reshape(steps, _K_CHUNK, n).astype(jnp.int32)

    def body(acc, slabs):
        a_c, b_c = slabs  # (m, ck), (ck, n)
        prod = product_fn(a_c[:, :, None], b_c[None, :, :])  # (m, ck, n)
        return acc + prod.sum(axis=1), None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, (a3, b3))
    if pad:
        if f00 is None:
            with jax.ensure_compile_time_eval():
                f00 = int(product_fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        acc = acc - f00 * pad
    return acc


def _exact_int_matmul(a8: Array, b8: Array) -> Array:
    return jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _sharded_dot(local_dot, a: Array, b: Array, part: Partitioning,
                 k_pad_unit: Optional[int]) -> Array:
    """(M,K)@(K,N) through shard_map: one lowering for int and float.

    Data-parallel M over ``part.m_axis``; K reduce-scattered over
    ``part.k_axis`` — each shard runs ``local_dot`` on its K slice (a
    substrate's own per-shard f(0,0) k-chunk-padding correction applies
    locally inside it), then partial sums combine via psum_scatter over the
    output's N dim when it divides the axis, plain psum otherwise (the
    output stays replicated over k). ``k_pad_unit`` is what one zero-padded
    K element contributes to every output (the wiring's f(0,0) for approx
    models, 0 for exact paths): global shard-divisibility zero-padding of K
    is corrected once with it after the reduce; None means no such
    correction exists, so non-divisible K must raise before calling here.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m, k = a.shape
    _, n = b.shape
    pm = (-m) % part.m_shards
    pk = (-k) % part.k_shards
    assert not (pk and k_pad_unit is None), "caller must reject this"
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk:
        b = jnp.pad(b, ((0, pk), (0, 0)))
    scatter = part.k_axis is not None and n % part.k_shards == 0

    def body(al, bl):
        out = local_dot(al, bl)
        if part.k_axis is not None:
            if scatter:
                out = jax.lax.psum_scatter(out, part.k_axis,
                                           scatter_dimension=1, tiled=True)
            else:
                out = jax.lax.psum(out, part.k_axis)
        return out

    out = shard_map(
        body, mesh=part.mesh,
        in_specs=(P(part.m_axis, part.k_axis), P(part.k_axis, None)),
        out_specs=P(part.m_axis, part.k_axis if scatter else None),
        check_rep=False,
    )(a, b)
    if pk and k_pad_unit:
        out = out - k_pad_unit * pk
    return out[:m] if pm else out


def _sharded_dot_int(substrate: "_SubstrateBase", a: Array, b: Array,
                     part: Partitioning) -> Array:
    """Integer ``_sharded_dot``: exact int32 reduce, f(0,0) pad unit."""
    k = a.shape[1]
    if substrate._f00 is None and k % part.k_shards:
        raise ValueError(
            f"{substrate.meta.spec}: K={k} must be a multiple of the k_axis "
            f"size ({part.k_shards}) — this substrate's correction is defined "
            "at contraction level (scalar_faithful=False), so the k-pad "
            "f(0,0) fix-up does not apply; pad K yourself or drop k_axis")
    return _sharded_dot(substrate.dot_int, a, b, part, substrate._f00)


def _sharded_dot_float(a: Array, b: Array, part: Partitioning) -> Array:
    """Float ``_sharded_dot`` (exact backend's mesh path): zero k-padding
    is exact in float, but the psum reduction order makes this ≈ (not
    bit-identical to) the unsharded float dot, as usual for float."""
    return _sharded_dot(jnp.matmul, a, b, part, k_pad_unit=0)


class _SubstrateBase:
    """Shared ``dot_general`` plumbing + deprecated wrappers."""

    meta: SubstrateMeta
    #: the scalar-product model's f(0,0) — the k-padding correction unit.
    #: 0 for exact backends, the wiring's compensation value for approx
    #: ones, None where no per-product value exists (approx_stat).
    _f00: Optional[int] = 0

    # -- raw product model ---------------------------------------------------

    def scalar(self, a: Array, b: Array) -> Array:
        raise NotImplementedError

    def dot_int(self, a: Array, b: Array) -> Array:
        """2-D (M,K)@(K,N) integer contraction (exact int32 adder)."""
        raise NotImplementedError

    def _stor(self, x: Array) -> Array:
        """Cast integer operands to the width's storage dtype (int8/int16)."""
        return jnp.asarray(x, quant.storage_dtype(self.meta.width))

    # -- telemetry -----------------------------------------------------------

    def _meter_hook(self, plan: "_Plan", a3: Optional[Array],
                    b3: Optional[Array],
                    site: Optional[str] = None) -> None:
        """Record this contraction on the ambient telemetry meter, if any.

        One global read when no :func:`repro.obs.meter.telemetry_scope`
        is active — the metered path is purely additive (counts / MACs /
        estimated energy, plus the opt-in error probe on integer
        operands), so outputs are bit-identical either way. ``site`` (from
        ``spec.site``) names the contraction site for per-site attribution.
        """
        meter = _current_meter()
        if meter is None:
            return
        meter.record_contraction(self.meta, plan.b, plan.m, plan.k, plan.n,
                                 site=site)
        if (meter.error_probe and a3 is not None
                and self.meta.mult_name != "exact"
                and jnp.issubdtype(a3.dtype, jnp.integer)):
            meter.probe(self.meta, self.scalar, a3, b3, site=site)

    # -- the contraction surface ---------------------------------------------

    def dot_general(self, x: Array, w: Array,
                    spec: Optional[ContractionSpec] = None) -> Array:
        """General contraction of ``x`` and ``w`` under this substrate.

        ``spec`` (default :class:`ContractionSpec`, i.e. plain matmul dims,
        integer domain, unpartitioned) carries dimension numbers, the
        quantization policy, and the mesh partitioning — see the class
        docstrings. Output layout matches ``jax.lax.dot_general``:
        ``(batch..., lhs_free..., rhs_free...)``.
        """
        spec = spec if spec is not None else ContractionSpec()
        x = jnp.asarray(x)
        w = jnp.asarray(w)
        plan = _plan_contraction(x.shape, w.shape, spec.dimension_numbers)
        if spec.quant is None:
            if not (jnp.issubdtype(x.dtype, jnp.integer)
                    and jnp.issubdtype(w.dtype, jnp.integer)):
                raise TypeError(
                    "integer-domain dot_general (spec.quant=None) needs "
                    f"integer operands, got {x.dtype}/{w.dtype}; pass a "
                    "QuantPolicy to contract float tensors")
            a3, b3 = plan.lhs3(x), plan.rhs3(w)
            self._meter_hook(plan, a3, b3, site=spec.site)
            out3 = self._contract3(a3, b3, spec.partitioning)
            return plan.unflatten(out3)
        q = spec.quant
        bits = q.bits if q.bits is not None else self.meta.width
        if bits > self.meta.width:
            raise ValueError(
                f"QuantPolicy.bits={bits} exceeds the substrate operand "
                f"width {self.meta.width} ({self.meta.spec}) — wider codes "
                "would wrap in the narrower multiplier")
        qa, sa = _quantize_operand(plan.lhs3(x), q.x_mode, q.x_scale,
                                   contract_axis=2, bits=bits, eps=q.eps)
        qb, sb = _quantize_operand(plan.rhs3(w), q.w_mode, q.w_scale,
                                   contract_axis=1, bits=bits, eps=q.eps)
        self._meter_hook(plan, qa, qb, site=spec.site)
        out3 = self._contract3(qa, qb, spec.partitioning)
        out3 = out3.astype(jnp.float32) * (sa * sb)
        return plan.unflatten(out3).astype(x.dtype)

    def _contract3(self, a3: Array, b3: Array,
                   partitioning: Optional[Partitioning]) -> Array:
        """(B,M,K)@(B,K,N) via the backend 2-D kernel (vmap over batch)."""
        if a3.shape[0] == 1:
            return self._contract2(a3[0], b3[0], partitioning)[None]
        if partitioning is not None:
            raise NotImplementedError(
                "partitioned dot_general with batch dimensions is not "
                "supported yet — shard the batch outside, or drop "
                "spec.partitioning")
        return jax.vmap(self.dot_int)(a3, b3)

    def _contract2(self, a: Array, b: Array,
                   partitioning: Optional[Partitioning]) -> Array:
        if partitioning is None:
            return self.dot_int(a, b)
        return _sharded_dot_int(self, a, b, partitioning)

    # -- deprecated wrappers (kept signatures; all route via dot_general) ----

    def dot_int8(self, a8: Array, b8: Array) -> Array:
        """Deprecated alias of :meth:`dot_int` — the name was a lie at
        N=16, where operands are int16."""
        return self.dot_int(a8, b8)

    def dot(self, x: Array, w: Array) -> Array:
        """``x @ w`` with this substrate as the scalar-product unit.

        Deprecated wrapper: ``dot_general`` with the plain matmul dims and
        the default :class:`QuantPolicy` (per-tensor dynamic activation
        scale, per-output-channel weight scales, substrate width).
        x: (..., K) activations (any float dtype); w: (K, N) weights.
        Returns x's dtype.
        """
        return self.dot_general(x, w, _DEFAULT_FLOAT_SPEC)

    # -- convolution ---------------------------------------------------------

    def conv2d(self, imgs: Array, kernel: Array) -> Array:
        """Batched 'same' integer conv (im2col + ``dot_general``); see
        nn.conv. Deprecated-stable wrapper around ``conv.conv2d_batched``."""
        from repro.nn import conv  # late import: conv consumes substrates

        return conv.conv2d_batched(imgs, kernel, self)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.meta.spec}>"


#: the historical ``dot`` behavior as a spec: plain matmul, default policy.
_DEFAULT_FLOAT_SPEC = ContractionSpec(quant=QuantPolicy())


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


def _reject_wiring(backend: str, mult_name: str | None) -> None:
    """Exact backends take no multiplier wiring — a suffix is a confused
    spec (e.g. ``"int8:design_du2022"`` meaning approx_*), not a no-op."""
    if mult_name not in (None, "exact"):
        raise ValueError(
            f"{backend} is an exact backend and takes no multiplier wiring "
            f"(got {mult_name!r}); use approx_bitexact/approx_lut/approx_stat "
            "to select a wiring.")


def _split_suffix(mult_name: str | None) -> tuple[str, int]:
    """Wiring suffix (possibly carrying ``@N``) → (base_name, width).

    An empty wiring name in front of a width (``"@4"``) is rejected, not
    defaulted: a config typo that drops the wiring but keeps ``@N`` would
    otherwise silently run the proposed design instead of the intended one.
    """
    base, n = mult.split_width(mult_name or "proposed")
    if not base:
        raise ValueError(
            f"malformed multiplier suffix {mult_name!r}: a width needs a "
            "wiring name (mult_name[@N]), e.g. 'proposed@4'")
    return base, n


class ExactSubstrate(_SubstrateBase):
    """Float reference: plain dot in the compute dtype, exact int contraction.

    The float path ignores the :class:`QuantPolicy` — this backend *is* the
    unquantized reference the quantized substrates are compared against.
    """

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("exact", mult_name)
        self._f00 = 0
        self.meta = SubstrateMeta("exact", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int(self, a, b):
        return _exact_int_matmul(self._stor(a), self._stor(b))

    def dot_general(self, x, w, spec: Optional[ContractionSpec] = None):
        spec = spec if spec is not None else ContractionSpec()
        x = jnp.asarray(x)
        if spec.quant is not None:
            # the quantization boundary is a no-op here by definition:
            # contract in the compute dtype (the historical `dot`)
            w = jnp.asarray(w, x.dtype)
            plan = _plan_contraction(x.shape, w.shape, spec.dimension_numbers)
            self._meter_hook(plan, None, None, site=spec.site)  # no probe
            if spec.partitioning is None:
                return jax.lax.dot_general(x, w, plan.dims)
            if plan.b != 1:
                raise NotImplementedError(
                    "partitioned dot_general with batch dimensions is not "
                    "supported yet")
            out3 = _sharded_dot_float(plan.lhs3(x)[0], plan.rhs3(w)[0],
                                      spec.partitioning)[None]
            return plan.unflatten(out3)
        return super().dot_general(x, w, spec)


class Int8Substrate(_SubstrateBase):
    """Symmetric int8 quantization boundary, exact int32 matmul."""

    def __init__(self, mult_name: str | None = None):
        _reject_wiring("int8", mult_name)
        self._f00 = 0
        self.meta = SubstrateMeta("int8", "exact", bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="mxu")

    def scalar(self, a, b):
        return mult.exact_multiply(a, b)

    def dot_int(self, a, b):
        return _exact_int_matmul(self._stor(a), self._stor(b))


class BitexactSubstrate(_SubstrateBase):
    """Every scalar product through the closed-form multiplier model.

    Supports any wiring at any width 3..16 (``"proposed@16"`` etc.)."""

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        _, self._fn, n = mult.resolve_multiplier(base, n)
        with jax.ensure_compile_time_eval():
            self._f00 = int(self._fn(jnp.zeros((), jnp.int32),
                                     jnp.zeros((), jnp.int32)))
        self.meta = SubstrateMeta("approx_bitexact", base, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="scalar-emulation", width=n)

    def scalar(self, a, b):
        return self._fn(a, b)

    def dot_int(self, a, b):
        return _bitexact_contract(self._stor(a), self._stor(b), self._fn,
                                  f00=self._f00)


class LutSubstrate(_SubstrateBase):
    """Gather-based contraction through the (2^N)² product LUT (N ≤ 8)."""

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                f"approx_lut needs an enumerable product table (width <= "
                f"{lut_lib.MAX_LUT_BITS}, got {n}); use approx_bitexact for "
                "wider operands")
        self._key = key
        self._f00 = int(lut_lib.f00(key))
        self.meta = SubstrateMeta("approx_lut", base, bit_exact=True,
                                  scalar_faithful=True, preferred_backend="any",
                                  cost_hint="gather", width=n)

    def _table(self) -> Array:
        return jnp.asarray(lut_lib.build_lut(self._key))

    def scalar(self, a, b):
        return lut_lib.lut_multiply(a, b, self._table())

    def dot_int(self, a, b):
        table = self._table()
        n = self.meta.width
        size, off = 1 << n, 1 << (n - 1)
        return _bitexact_contract(
            self._stor(a), self._stor(b),
            lambda x, y: table[(x + off) & (size - 1), (y + off) & (size - 1)],
            f00=self._f00)


class StatSubstrate(_SubstrateBase):
    """Exact int32 matmul + separable statistical error model.

    E[e(a,b)] ≈ r[a] + c[b] − µ, where e is the multiplier's error LUT and
    r/c its row/column means. Adds two gathers + two rank-1 terms, lowers to
    MXU-friendly HLO, and is the deployment-scale stand-in used by the
    multi-pod dry-runs (the Pallas kernel replaces it on real hardware).
    Beyond-paper contribution. The correction is defined at contraction level
    (``scalar_faithful=False``): ``dot_int`` rounds the summed correction
    once per output element, while ``scalar`` rounds per product. Widths ≤ 8
    (the separable model is fit on the exhaustive error LUT).
    """

    def __init__(self, mult_name: str | None = None):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                "approx_stat fits its separable error model on the "
                f"exhaustive error LUT (width <= {lut_lib.MAX_LUT_BITS}, "
                f"got {n}); use approx_bitexact for wider operands")
        self._key = key
        self._f00 = None  # the correction is not separable per product
        self.meta = SubstrateMeta("approx_stat", base, bit_exact=False,
                                  scalar_faithful=False, preferred_backend="any",
                                  cost_hint="mxu", width=n)

    def scalar(self, a, b):
        n = self.meta.width
        off = 1 << (n - 1)
        r, c, _mu = _stat_tables(self._key)
        a = mult.wrap_operand(jnp.asarray(a, jnp.int32), n)
        b = mult.wrap_operand(jnp.asarray(b, jnp.int32), n)
        corr = jnp.asarray(r)[a + off] + jnp.asarray(c)[b + off]
        return a * b + corr.astype(jnp.int32)

    def dot_int(self, a, b):
        n = self.meta.width
        off = 1 << (n - 1)
        # wrap into the width's operand domain first (module contract) so
        # both the exact matmul and the correction gathers see the same
        # operands the scalar model does
        aw = mult.wrap_operand(jnp.asarray(a, jnp.int32), n)
        bw = mult.wrap_operand(jnp.asarray(b, jnp.int32), n)
        # wrapped values fit the storage dtype (width ≤ 8 here), so the
        # contraction keeps the int8 MXU path
        exact = _exact_int_matmul(self._stor(aw), self._stor(bw))
        r, c, _mu = _stat_tables(self._key)
        ra = jnp.asarray(r)[aw + off].sum(axis=1)  # (m,)
        cb = jnp.asarray(c)[bw + off].sum(axis=0)  # (n,)
        corr = ra[:, None] + cb[None, :]
        return exact + corr.astype(jnp.int32)


class PallasSubstrate(_SubstrateBase):
    """Tiled Pallas TPU contraction for any wiring at widths 3..8.

    Two kernel strategies behind one spec family, both bit-identical to
    ``approx_bitexact`` at the same wiring/width and both running in
    interpret mode off-TPU so the code path is testable on CPU:

    * ``"closed_form"`` — the wiring's *generated* closed form
      (``kernels.closed_form.make_closed_form``), pure VPU integer algebra
      through the vectorized-k-slab ``kernels/approx_matmul`` (cost hint
      ``vpu``). The default for every CSP wiring at every width 3..8 —
      non-proposed wirings no longer pay a per-product gather.
    * ``"lut"`` — the LUT-input kernel (``kernels/lut_matmul``): one
      gather per product into the wiring's flat (2^N · 2^N,) product
      table, VMEM-resident for N ≤ 8 (cost hint ``gather``). The
      automatic fallback for product models with no CSP closed form
      (``"exact"``); forceable with ``kernel="lut"`` for A/B benchmarks.

    Convolutions additionally expose :meth:`fused_conv2d` — the fused
    in-kernel-im2col conv (``kernels/fused_conv``) that
    ``nn.conv.conv2d_batched`` auto-selects as its fast path.

    Widths above ``MAX_LUT_BITS`` are rejected — f(0,0) bookkeeping and
    the LUT fallback need an enumerable product table; use
    ``approx_bitexact`` for wider operands.
    """

    def __init__(self, mult_name: str | None = None, kernel: str = "auto"):
        base, n = _split_suffix(mult_name)
        key, _, n = mult.resolve_multiplier(base, n)
        if n > lut_lib.MAX_LUT_BITS:
            raise ValueError(
                "approx_pallas needs an enumerable product table for its "
                f"LUT kernel (width <= {lut_lib.MAX_LUT_BITS}, got {n}); "
                "use approx_bitexact for wider operands")
        if kernel not in ("auto", "closed_form", "lut"):
            raise ValueError(
                f"unknown approx_pallas kernel strategy {kernel!r} "
                "(known: auto, closed_form, lut)")
        self._key = key
        self._f00 = int(lut_lib.f00(key))
        self._product_fn = None
        if kernel in ("auto", "closed_form"):
            from repro.kernels.closed_form import make_closed_form

            try:
                self._product_fn = make_closed_form(key)
            except ValueError:  # no CSP structure (e.g. "exact")
                if kernel == "closed_form":
                    raise
        self._kernel_kind = "closed_form" if self._product_fn else "lut"
        self.meta = SubstrateMeta(
            "approx_pallas", base, bit_exact=True, scalar_faithful=True,
            preferred_backend="tpu",
            cost_hint="vpu" if self._product_fn else "gather", width=n)

    def _table(self) -> Array:
        return jnp.asarray(lut_lib.flat_lut(self._key))

    def scalar(self, a, b):
        if self._product_fn is not None:
            return self._product_fn(a, b)
        return lut_lib.lut_multiply(
            a, b, jnp.asarray(lut_lib.build_lut(self._key)))

    def dot_int(self, a, b):
        a = jnp.asarray(a, jnp.int32)
        b = jnp.asarray(b, jnp.int32)
        if self._product_fn is not None:
            from repro.kernels.approx_matmul.ops import closed_form_matmul

            return closed_form_matmul(a, b, self._key)
        from repro.kernels.lut_matmul.ops import lut_matmul

        return lut_matmul(a, b, self._table())

    def fused_conv2d(self, imgs: Array, kernel: Array) -> Array:
        """Fused in-kernel-im2col conv (``kernels/fused_conv``): batched
        'same' conv with no host-side patch tensor, bit-identical to the
        im2col + ``dot_general`` path. The kernel taps must be concrete
        (they specialize the Pallas kernel) — ``conv.conv2d_batched``
        guards this and falls back to im2col for traced kernels."""
        from repro.kernels.fused_conv.ops import fused_conv2d

        return fused_conv2d(imgs, kernel, self._key,
                            kernel_kind=self._kernel_kind)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[str], ProductSubstrate]] = {}


def register_substrate(name: str,
                       factory: Callable[..., ProductSubstrate]) -> None:
    """Register a backend under ``name``; factory takes a mult suffix (or
    ``None`` when the spec carried no wiring — each backend applies its own
    default or rejects)."""
    _FACTORIES[name] = factory


def list_substrates() -> list[str]:
    """Registered backend names (stable order)."""
    return sorted(_FACTORIES)


class SpecParts(NamedTuple):
    """Parsed ``"backend[:mult_name[@N]]"`` spec string."""

    backend: str
    mult_name: str
    width: int


def _split_spec(spec: str) -> tuple[str, str | None]:
    """Validated ``"backend[:mult_name[@N]]"`` split → (backend, suffix).

    Rejects malformed specs instead of silently normalizing them: an empty
    backend or wiring suffix (``"exact:"``, ``":proposed"``) and any
    whitespace (``"approx_pallas:proposed@8 "``) are grammar errors — a
    stray character in a config would otherwise parse as a different,
    well-formed spec.
    """
    s = str(spec)
    if not s or any(c.isspace() for c in s):
        raise ValueError(
            f"malformed substrate spec {spec!r}: specs follow "
            "backend[:mult_name[@N]] with no whitespace")
    name, sep, suffix = s.partition(":")
    if not name or (sep and not suffix):
        part = "backend" if not name else "wiring suffix"
        raise ValueError(
            f"malformed substrate spec {spec!r}: empty {part} — specs "
            "follow backend[:mult_name[@N]]")
    return name, (suffix if sep else None)


def parse_spec(spec: str) -> SpecParts:
    """``"backend[:mult_name[@N]]"`` → (backend, mult_name, width).

    A missing wiring reads as ``"proposed"`` (the approx backends' default;
    exact backends take no wiring at all); a missing width as 8. Malformed
    specs (empty parts — including an empty wiring name before ``@N`` —
    and whitespace) raise ``ValueError``.
    """
    name, suffix = _split_spec(spec)
    base, width = mult.split_width(suffix or "proposed")
    if not base:
        raise ValueError(
            f"malformed substrate spec {spec!r}: empty wiring name before "
            "'@' — specs follow backend[:mult_name[@N]]")
    return SpecParts(name, base, width)


@functools.lru_cache(maxsize=None)
def get_substrate(spec: str = "exact",
                  mult_name: str | None = None) -> ProductSubstrate:
    """Resolve a spec string to a (cached) substrate instance.

    ``spec`` may carry a wiring+width suffix (``"approx_lut:design_du2022"``,
    ``"approx_bitexact:proposed@16"``); an explicit ``mult_name`` argument
    (which may itself carry ``@N``) overrides the suffix. Backends validate
    the wiring and width: approx backends default a missing wiring to
    ``"proposed"`` at width 8, exact backends reject any suffix outright.
    """
    name, suffix = _split_spec(spec)
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown product substrate: {name!r} (known: {list_substrates()})")
    return _FACTORIES[name](mult_name or suffix or None)


def as_substrate(s: "str | ProductSubstrate") -> ProductSubstrate:
    """Accept either a spec string or an already-resolved substrate."""
    if isinstance(s, str):
        return get_substrate(s)
    return s


register_substrate("exact", ExactSubstrate)
register_substrate("int8", Int8Substrate)
register_substrate("approx_bitexact", BitexactSubstrate)
register_substrate("approx_lut", LutSubstrate)
register_substrate("approx_stat", StatSubstrate)
register_substrate("approx_pallas", PallasSubstrate)
