"""Jit'd public wrapper for the Laplacian edge-detection kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.laplacian_conv.kernel import laplacian_conv_pallas

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_h",))
def laplacian_conv(img_i32, block_h: int = 64):
    """Approximate Laplacian edge map of a signed-domain (H, W) image."""
    img = jnp.asarray(img_i32, jnp.int32)
    h, w = img.shape
    bh = min(block_h, h)
    pad_h = (-h) % bh
    padded = jnp.pad(img, ((1, 1 + pad_h), (1, 1)))
    top = padded[0:h + pad_h, :]
    mid = padded[1:h + pad_h + 1, :]
    bot = padded[2:h + pad_h + 2, :]
    out = laplacian_conv_pallas(top, mid, bot, block_h=bh, interpret=_INTERPRET)
    return out[:h, :]
