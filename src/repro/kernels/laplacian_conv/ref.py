"""Pure-jnp oracle for the Laplacian edge-detection kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import multiplier as mult
from repro.nn import conv


def laplacian_conv_ref(img_i32):
    """'same' Laplacian conv of signed-domain pixels via the core model."""
    return conv.conv2d_int(
        jnp.asarray(img_i32, jnp.int32), jnp.asarray(conv.LAPLACIAN), mult.approx_multiply
    )
