"""Streaming Laplacian edge-detection Pallas kernel (paper Fig. 8).

TPU adaptation of the paper's FPGA row-buffer architecture: the image is
processed in row-band tiles (the VMEM analogue of line buffers). The halo
exchange is expressed as three row-shifted views of the zero-padded image
(top / centre / bottom line buffers) so every BlockSpec uses plain blocked
indexing — no overlapping reads needed.

Because the kernel coefficients are constants, the closed form specializes:
f(x, 8) for the centre tap and f(x, −1) for the eight neighbours — 9 taps
collapse into 2 elementwise product maps + 9 shifted adds (exact adder).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.closed_form import approx_product_i32


def _kernel(top_ref, mid_ref, bot_ref, o_ref):
    top = top_ref[...].astype(jnp.int32)    # (bh, W+2) row y-1
    mid = mid_ref[...].astype(jnp.int32)    # (bh, W+2) row y
    bot = bot_ref[...].astype(jnp.int32)    # (bh, W+2) row y+1
    w = top.shape[1] - 2

    f8_mid = approx_product_i32(mid, jnp.full((), 8, jnp.int32))
    acc = f8_mid[:, 1:1 + w]
    for row in (top, mid, bot):
        fm1 = approx_product_i32(row, jnp.full((), -1, jnp.int32))
        for dj in (0, 1, 2):
            if row is mid and dj == 1:
                continue
            acc = acc + fm1[:, dj:dj + w]
    o_ref[...] = acc


def laplacian_conv_pallas(top, mid, bot, *, block_h: int = 64,
                          interpret: bool = False):
    """Row-shifted views (H, W+2) of the zero-padded image → (H, W) edges.

    top/mid/bot: padded[0:H], padded[1:H+1], padded[2:H+2] row bands.
    H must be a multiple of block_h (ops.py pads).
    """
    h, wp = mid.shape
    w = wp - 2
    grid = (h // block_h,)
    row_spec = pl.BlockSpec((block_h, wp), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((block_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.int32),
        interpret=interpret,
    )(top, mid, bot)
