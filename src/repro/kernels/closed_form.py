"""Kernel-friendly closed form of the proposed approximate multiplier.

The core-library model (`repro.core.multiplier`) expands all 28 truncated
partial products. For the Pallas kernels we use an algebraically identical
but much cheaper form (≈25 VPU integer ops per element):

* truncation via the 7-term identity
    trunc(a,b) = Σ_{i=0}^{6} a_i · 2^i · (b & (2^{7-i} − 1))
  (each column sum collapses into a masked value of b);
* the single approximate compressor's error (e_C1a) as arithmetic on four
  partial-product bits (the exact compressors contribute no error).

`tests/test_kernels_closed_form.py` asserts bit-equality with the core model
on all 65 536 operand pairs.
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray


def approx_product_i32(a: Array, b: Array) -> Array:
    """Proposed approximate signed product; a, b int32 in [-128, 127]."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    ab = a * b

    # truncated LSP columns 0..6 (7-term masked-operand identity)
    t = jnp.zeros_like(ab)
    for i in range(7):
        t = t + (((a >> i) & 1) * ((b & ((1 << (7 - i)) - 1)) << i))

    # NAND→1 conversion ¬(a7·b0) → constant (error +2^7 when a7·b0)
    conv = ((a >> 7) & 1) & (b & 1)

    # approximate A+B+C+D+1 compressor at column 7
    na0b7 = 1 - ((a & 1) & ((b >> 7) & 1))
    p16 = ((a >> 1) & 1) & ((b >> 6) & 1)
    p25 = ((a >> 2) & 1) & ((b >> 5) & 1)
    p34 = ((a >> 3) & 1) & ((b >> 4) & 1)
    s = p16 + p25 + p34
    approx_v = 2 * (na0b7 | (s > 0)).astype(jnp.int32) + 1 - (na0b7 & (s == 0))
    e1a = approx_v - (na0b7 + s + 1)

    raw = ab - t + 192 + (conv << 7) + (e1a << 7)

    # wrap to 16-bit two's complement
    u = raw & 0xFFFF
    return jnp.where(u >= 0x8000, u - 0x10000, u)
