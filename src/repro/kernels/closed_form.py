"""Kernel-friendly closed forms of the CSP approximate multipliers.

Two layers:

* :func:`approx_product_i32` — the hand-derived closed form of the paper's
  proposed 8-bit design (≈25 VPU integer ops per element), kept verbatim as
  the reference the generator is checked against.
* :func:`make_closed_form` — the same algebra generated for *any* CSP
  wiring in ``core.multiplier.WIRINGS`` at any width 3..16, from the slot
  taps and the compressor truth tables:

      approx(a,b) = a·b − trunc + comp_n + 2^{n-1}·(a_{n-1}·b_0)
                    + 2^{n-1}·(e_C1a + e_C1b) + 2^n·e_C3     (mod 2^{2n})

  with the truncation collapsed into the (n−1)-term masked-operand identity
      trunc(a,b) = Σ_{i=0}^{n-2} a_i · 2^i · (b & (2^{n-1-i} − 1))
  and each slot error evaluated as a compare-select sum over the *nonzero*
  truth-table entries (exact compressors vanish entirely) — pure VPU
  integer ops, no gathers, so every wiring runs on the vectorized Pallas
  kernels instead of paying the LUT-gather cost.

``tests/test_kernels_closed_form.py`` asserts bit-equality of
:func:`approx_product_i32` with the core model on all 65 536 operand pairs;
``tests/test_fused_conv.py`` extends the contract to the generated forms
(exhaustive at N=4, sampled at the other widths).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressors as comp
from repro.core import multiplier as mult

Array = jnp.ndarray


def approx_product_i32(a: Array, b: Array) -> Array:
    """Proposed approximate signed product; a, b int32 in [-128, 127]."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    ab = a * b

    # truncated LSP columns 0..6 (7-term masked-operand identity)
    t = jnp.zeros_like(ab)
    for i in range(7):
        t = t + (((a >> i) & 1) * ((b & ((1 << (7 - i)) - 1)) << i))

    # NAND→1 conversion ¬(a7·b0) → constant (error +2^7 when a7·b0)
    conv = ((a >> 7) & 1) & (b & 1)

    # approximate A+B+C+D+1 compressor at column 7
    na0b7 = 1 - ((a & 1) & ((b >> 7) & 1))
    p16 = ((a >> 1) & 1) & ((b >> 6) & 1)
    p25 = ((a >> 2) & 1) & ((b >> 5) & 1)
    p34 = ((a >> 3) & 1) & ((b >> 4) & 1)
    s = p16 + p25 + p34
    approx_v = 2 * (na0b7 | (s > 0)).astype(jnp.int32) + 1 - (na0b7 & (s == 0))
    e1a = approx_v - (na0b7 + s + 1)

    raw = ab - t + 192 + (conv << 7) + (e1a << 7)

    # wrap to 16-bit two's complement
    u = raw & 0xFFFF
    return jnp.where(u >= 0x8000, u - 0x10000, u)


# ---------------------------------------------------------------------------
# Generated closed forms (any wiring × width)
# ---------------------------------------------------------------------------


def _slot_error_terms(c: comp.Compressor) -> list[tuple[int, int]]:
    """(packed_index, error) pairs where the truth table deviates from exact."""
    return [(v, int(e)) for v, e in enumerate(np.asarray(c.errors)) if e]


def make_closed_form(key: str, n: int | None = None):
    """Vectorized closed-form product fn for a CSP wiring (``"name[@N]"``).

    Returns ``fn(a, b) -> int32`` bit-identical to
    ``core.multiplier.make_multiplier`` at the same wiring/width — operands
    wrap into the signed n-bit domain, output wraps to 2n-bit two's
    complement. ``csp_*`` aliases resolve; ``"exact"`` is rejected (it has
    no CSP structure — use ``mult.exact_multiply``).
    """
    base, kn = mult.split_width(key)
    width = n if n is not None else kn
    base = mult.WIRING_ALIASES.get(base, base)
    return _build_closed_form(base, width)


@functools.lru_cache(maxsize=None)
def _build_closed_form(base: str, nb: int):
    wiring = mult.get_wiring(base)  # rejects "exact" / unknown names
    comp_const = mult.compensation_constant(nb)  # validates the width
    t1a, t1b, t3 = mult.csp_slot_taps(nb)
    # slot spec: (compressor, index of the negative-pp row or None, pos taps)
    slot_specs = ((wiring.c1a, 0, t1a), (wiring.c1b, None, t1b),
                  (wiring.c3, 1, t3))

    def fn(a: Array, b: Array) -> Array:
        a = mult.wrap_operand(jnp.asarray(a, jnp.int32), nb)
        b = mult.wrap_operand(jnp.asarray(b, jnp.int32), nb)
        ab = a * b

        # truncation via the (n−1)-term masked-operand identity
        t = jnp.zeros_like(ab)
        for i in range(nb - 1):
            t = t + (((a >> i) & 1) * ((b & ((1 << (nb - 1 - i)) - 1)) << i))

        # NAND→1 conversion ¬(a_{n-1}·b_0) → constant
        conv = ((a >> (nb - 1)) & 1) & (b & 1)

        def slot_error(c, neg_row, taps):
            terms = _slot_error_terms(c)
            if not terms:  # exact compressor: no error, no index to pack
                return None
            bits = []
            if neg_row is not None:
                bits.append(1 - (((a >> neg_row) & 1) & ((b >> (nb - 1)) & 1)))
            bits += [((a >> i) & 1) & ((b >> j) & 1) for i, j in taps]
            bits = bits[: c.n_inputs]
            while len(bits) < c.n_inputs:
                bits.append(jnp.zeros_like(ab))
            idx = comp.pack_bits(bits)
            err = jnp.zeros_like(ab)
            for v, e in terms:
                err = err + e * (idx == v).astype(jnp.int32)
            return err

        raw = ab - t + comp_const + (conv << (nb - 1))
        for (c, neg_row, taps), shift in zip(slot_specs, (nb - 1, nb - 1, nb)):
            err = slot_error(c, neg_row, taps)
            if err is not None:
                raw = raw + (err << shift)
        return mult.wrap_to_width(raw, 2 * nb)

    fn.__name__ = f"closed_form_{base}@{nb}"
    return fn


@functools.lru_cache(maxsize=None)
def closed_form_f00(key: str, n: int | None = None) -> int:
    """The wiring's product at (0, 0) — the k-padding correction unit.

    Computed from the generated closed form itself (works at any width,
    unlike the enumerable-table ``core.lut.f00``).
    """
    fn = make_closed_form(key, n)
    with jax.ensure_compile_time_eval():
        return int(fn(jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
