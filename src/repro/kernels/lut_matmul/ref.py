"""Pure-jnp oracle for the LUT-input approximate matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.kernels.lut_matmul.kernel import table_width


def lut_matmul_ref(a, b, table):
    """sum_k lut[a[m,k], b[k,n]] through the 2-D LUT gather.

    Materializes the (M, K, N) product tensor — oracle for small shapes
    only. ``table`` may be the flat (2^{2n},) or the square (2^n, 2^n) LUT.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    table = jnp.asarray(table, jnp.int32)
    if table.ndim == 1:
        n_bits = table_width(table.shape[0])
        table = table.reshape(1 << n_bits, 1 << n_bits)
    prod = lut_lib.lut_multiply(a[:, :, None], b[None, :, :], table)
    return prod.sum(axis=1).astype(jnp.int32)
