"""Jit'd public wrapper for the LUT-input approximate matmul kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blocking
from repro.kernels.lut_matmul.kernel import lut_matmul_pallas, table_width
from repro.obs.trace import trace_span


def lut_matmul(a, b, table, block_m: int = 128, block_n: int = 128,
               block_k: int = 128, k_chunk: int = 8):
    """(M,K) @ (K,N) under the approximate multiplier defined by ``table``.

    ``table`` is the flat (2^{2n},) product LUT of any wiring/width ≤ 8
    (``core.lut.flat_lut``). Pads every dim to its block multiple. Zero
    padding of the contraction dim injects f(0,0) per padded k element (the
    compensation constant fires on zero operands — faithful to the netlist),
    which is looked up from the table — it differs per wiring and width —
    and subtracted back. ``k_chunk=1`` recovers the pre-vectorization
    per-k gather walk (kept as the benchmark baseline).
    """
    (m, k), (_, n) = jnp.shape(a), jnp.shape(b)
    with trace_span("kernel.lut_matmul", "kernel", m=m, k=k, n=n):
        return _lut_matmul_jit(a, b, table, block_m, block_n, block_k,
                               k_chunk)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k", "k_chunk"))
def _lut_matmul_jit(a, b, table, block_m, block_n, block_k, k_chunk):
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    table = jnp.asarray(table, jnp.int32)
    n_bits = table_width(table.shape[0])
    off = 1 << (n_bits - 1)
    f00 = table[(off << n_bits) | off]  # this wiring's product at (0,0)
    return blocking.pad_crop_correct(
        a, b, f00,
        lambda ap, bp, bm, bn, bk: lut_matmul_pallas(
            ap, bp, table, block_m=bm, block_n=bn, block_k=bk,
            k_chunk=k_chunk, interpret=blocking.resolve_interpret()),
        block_m=block_m, block_n=block_n, block_k=block_k)
