"""Tiled LUT-input approximate matmul Pallas kernel (any wiring, N ≤ 8).

Width- and wiring-generic sibling of ``kernels/approx_matmul``: instead of
a closed form, the scalar product is a gather into a flat ``(2^N · 2^N,)``
int32 product table (``core.lut.flat_lut``), so every wiring in
``core.multiplier.ALL_MULTIPLIERS`` — and every enumerable width 3..8 —
runs on the same kernel. (Since the closed-form generator landed, the LUT
kernel is the *fallback* path: ``PallasSubstrate`` prefers the generated
VPU kernel and keeps this one for product models with no CSP structure.)
The gather index for a product f(a, b) is

    idx = ((a + 2^(N-1)) & (2^N - 1)) << N  |  ((b + 2^(N-1)) & (2^N - 1))

which both biases the signed operands into table rows/cols and wraps
out-of-range ints to their low-N-bits value — the same operand-wraparound
semantics the closed form and the 2-D LUT gather implement.

Tiling matches ``approx_matmul``: grid (M/bm, N/bn, K/bk); the (bm, bn)
output block is revisited across the k dimension (TPU sequential grid) and
accumulated in place; the inner k-slab is walked in ``k_chunk``-wide slabs,
each indexing a (bm, kc, bn) block and resolving it with one batched VMEM
gather (``k_chunk=1`` recovers the historical per-k rank-1 walk). The
table rides along as a VMEM-resident input (256 KiB at N=8, the worst
case). Interpret mode runs the identical kernel body off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocking
from repro.kernels.approx_matmul.kernel import resolve_k_chunk


def table_width(size: int) -> int:
    """Operand width N implied by a flat table length 2^(2N)."""
    n = (max(int(size), 1).bit_length() - 1) // 2
    if (1 << (2 * n)) != size:
        raise ValueError(
            f"not a flat product-LUT length: {size} (expected 2^(2N) for an "
            "operand width N; build it with core.lut.flat_lut)")
    return n


def _lut_matmul_kernel(a_ref, b_ref, t_ref, o_ref, *, block_k: int,
                       k_chunk: int, n_bits: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mask = (1 << n_bits) - 1
    off = 1 << (n_bits - 1)
    a = a_ref[...].astype(jnp.int32)  # (bm, bk)
    b = b_ref[...].astype(jnp.int32)  # (bk, bn)
    table = t_ref[...]                # (2^{2n},) flat product table

    def body(j, acc):
        a_s = jax.lax.dynamic_slice_in_dim(a, j * k_chunk, k_chunk, axis=1)
        b_s = jax.lax.dynamic_slice_in_dim(b, j * k_chunk, k_chunk, axis=0)
        ai = (a_s + off) & mask                      # (bm, kc)
        bi = (b_s + off) & mask                      # (kc, bn)
        idx = (ai[:, :, None] << n_bits) | bi[None, :, :]  # (bm, kc, bn)
        return acc + jnp.take(table, idx, axis=0).sum(axis=1)

    acc = jax.lax.fori_loop(0, block_k // k_chunk, body, jnp.zeros_like(o_ref))
    o_ref[...] += acc


def lut_matmul_pallas(a, b, table, *, block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, k_chunk: int = 8,
                      interpret: bool = False):
    """(M,K) @ (K,N) contraction with the scalar product read from ``table``.

    a: (M, K) int32; b: (K, N) int32; table: flat (2^{2n},) int32 product
    LUT (``core.lut.flat_lut``). Returns (M, N) int32. ``k_chunk`` is
    clamped to a divisor of the block. Every dim must be a multiple of its
    block size — ``ops.lut_matmul`` pads arbitrary shapes and corrects the
    f(0,0) padding artifact; direct callers get a loud error instead of
    silent garbage.
    """
    m, k = a.shape
    _, n = b.shape
    blocking.check_kernel_shapes(
        "lut_matmul_pallas", "kernels.lut_matmul.ops.lut_matmul",
        a.shape, b.shape, block_m, block_n, block_k)
    n_bits = table_width(table.shape[0])
    k_chunk = resolve_k_chunk(k_chunk, block_k)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_lut_matmul_kernel, block_k=block_k,
                          k_chunk=k_chunk, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            # the whole flat table, resident in VMEM at every grid step
            pl.BlockSpec((table.shape[0],), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b, table)
