"""Jit'd public wrapper for the fused conv+multiply kernel.

``fused_conv2d(imgs, kernel, mult_key)`` runs a batched 'same' integer
convolution entirely inside one Pallas kernel — no host-side im2col patch
tensor. Two product strategies, selected by ``kernel_kind``:

* ``"closed_form"`` — the wiring's generated closed form
  (``kernels.closed_form.make_closed_form``): pure VPU integer algebra,
  partially constant-folded per static tap coefficient;
* ``"lut"`` — the wiring's flat (2^{2N},) product LUT rides along as a
  VMEM-resident kernel input; each distinct tap coefficient costs one
  batched gather at a static column offset (the fallback for product
  models with no CSP structure, e.g. ``"exact"``).

The default ``"auto"`` picks the closed form whenever the wiring has one
and falls back to the LUT otherwise — same policy as ``PallasSubstrate``.

The kernel taps must be *concrete* integers (they specialize the kernel);
``nn.conv.conv2d_batched`` falls back to the im2col reference path when
the kernel array is traced.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.kernels import blocking
from repro.kernels.closed_form import make_closed_form
from repro.kernels.fused_conv.kernel import fused_conv_pallas
from repro.obs.trace import trace_span

KERNEL_KINDS = ("auto", "closed_form", "lut")


def _lut_tap_product(n_bits: int):
    """Product fn gathering the flat table at a static column offset."""
    off, mask = 1 << (n_bits - 1), (1 << n_bits) - 1

    def fn(tile, c, table):
        idx = (((tile + off) & mask) << n_bits) | ((int(c) + off) & mask)
        return jnp.take(table, idx, axis=0)

    return fn


@functools.lru_cache(maxsize=None)
def _fused_runner(key: str, kernel_kind: str, taps: tuple, block_h: int,
                  interpret: bool):
    table = None
    if kernel_kind == "auto":
        try:
            make_closed_form(key)
            kernel_kind = "closed_form"
        except ValueError:  # no CSP wiring (e.g. "exact") — serve via LUT
            kernel_kind = "lut"
    if kernel_kind == "closed_form":
        cf = make_closed_form(key)
        product_fn = lambda tile, c, _table: cf(tile, c)  # noqa: E731
    elif kernel_kind == "lut":
        flat = lut_lib.flat_lut(key)
        table = jnp.asarray(flat, jnp.int32)
        product_fn = _lut_tap_product(flat.shape[0].bit_length() // 2)
    else:
        raise ValueError(
            f"unknown fused-conv kernel kind {kernel_kind!r} "
            f"(known: {KERNEL_KINDS})")
    kh, kw = len(taps), len(taps[0])
    ph, pw = kh // 2, kw // 2

    @jax.jit
    def run(imgs):
        imgs = jnp.asarray(imgs, jnp.int32)
        _, h, w = imgs.shape
        bh = min(block_h, blocking.ceil_to(h, blocking.SUBLANE))
        pad_h = (-h) % bh
        hb = h + pad_h
        padded = jnp.pad(imgs, ((0, 0), (ph, ph + pad_h), (pw, pw)))
        views = tuple(
            jax.lax.slice_in_dim(padded, di, di + hb, axis=1)
            for di in range(kh))
        out = fused_conv_pallas(views, taps, product_fn, width_out=w,
                                block_h=bh, table=table, interpret=interpret)
        return out[:, :h, :]

    return run


def fused_conv2d(imgs, kernel, mult_key: str = "proposed", *,
                 kernel_kind: str = "auto", block_h: int = 64,
                 interpret: bool | None = None):
    """Batched 'same' conv of (B, H, W) int32 images, fused in one kernel.

    ``kernel`` must be a concrete (kh, kw) int array — the taps specialize
    the kernel (a traced kernel raises; use the im2col path for that).
    Coefficients outside the wiring's signed N-bit operand range wrap, per
    the multipliers' two's-complement contract — identical semantics to
    the im2col + ``dot_general`` path, which this is bit-identical to.
    """
    taps = tuple(tuple(int(c) for c in row) for row in np.asarray(kernel))
    key = mult.canonical_key(mult_key)
    run = _fused_runner(key, kernel_kind, taps, block_h,
                        blocking.resolve_interpret(interpret))
    shape = jnp.shape(imgs)
    with trace_span("kernel.fused_conv2d", "kernel", mult=key,
                    shape="x".join(map(str, shape))):
        return run(imgs)
