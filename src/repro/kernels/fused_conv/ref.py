"""Pure-jnp oracles for the fused conv kernel.

``laplacian_conv_ref`` is the parity oracle absorbed from the retired
single-image ``kernels/laplacian_conv`` package (kept verbatim: 'same'
Laplacian conv of signed-domain pixels through the core scalar model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import multiplier as mult
from repro.nn import conv


def fused_conv_ref(imgs, kernel, mult_key: str = "proposed"):
    """Batched 'same' conv via the scalar tap loop (``conv.conv2d_int``)."""
    _, fn, _ = mult.resolve_multiplier(mult_key)
    kernel = jnp.asarray(kernel, jnp.int32)
    imgs = jnp.asarray(imgs, jnp.int32)
    return jax.vmap(lambda im: conv.conv2d_int(im, kernel, fn))(imgs)


def laplacian_conv_ref(img_i32):
    """'same' Laplacian conv of signed-domain pixels via the core model."""
    return conv.conv2d_int(
        jnp.asarray(img_i32, jnp.int32), jnp.asarray(conv.LAPLACIAN),
        mult.approx_multiply)
