"""Fused im2col + approximate-product conv Pallas kernel (paper Fig. 8).

TPU adaptation of the paper's FPGA row-buffer architecture, batched and
wiring-generic. Where ``nn.conv.conv2d_batched`` materializes a
(B, H, W, kh·kw) patch tensor in HBM and contracts it with a tiled matmul,
this kernel never builds the patch tensor: im2col happens *inside* the
kernel from a (block_h, W_padded) image tile in VMEM.

Halo exchange: overlapping row windows are not expressible with blocked
BlockSpec indexing, so the ops wrapper passes ``kh`` row-shifted views of
the zero-padded batch (the VMEM analogue of the paper's line buffers; the
idiom of the retired single-image ``kernels/laplacian_conv``). Inside the
kernel the kh·kw taps are static Python ints, so the products collapse
into one elementwise product map per *distinct* coefficient (the 3×3
Laplacian has two: f(x, 8) and f(x, −1)) evaluated on the whole tile,
followed by kh·kw shifted adds — exact int32 accumulation, no gathers for
closed-form product models.

Bit-identity: each output pixel accumulates exactly the products
f(x[di,dj], taps[di,dj]) over the zero-padded window — the same terms, in
the same int32 ring, as the im2col + ``dot_general`` reference path, and
no contraction-dim padding ever happens (K = kh·kw is contracted in full),
so no f(0,0) correction is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(*refs, taps, width_out, product_fn, has_table):
    if has_table:  # flat product LUT rides along as a VMEM-resident input
        view_refs, t_ref, o_ref = refs[:-2], refs[-2], refs[-1]
        table = t_ref[...]
    else:
        view_refs, o_ref = refs[:-1], refs[-1]
        table = None
    w = width_out
    acc = jnp.zeros(o_ref.shape[1:], jnp.int32)  # (bh, w)
    for di, vref in enumerate(view_refs):
        tile = vref[0].astype(jnp.int32)  # (bh, w + pad); row band di
        row = [int(c) for c in taps[di]]
        maps = {}
        for c in row:
            if c not in maps:  # one product map per distinct coefficient
                maps[c] = product_fn(tile, c, table)
        for dj, c in enumerate(row):
            acc = acc + jax.lax.slice_in_dim(maps[c], dj, dj + w, axis=1)
    o_ref[...] = acc[None]


def fused_conv_pallas(views, taps, product_fn, *, width_out: int,
                      block_h: int, table=None, interpret: bool = False):
    """Row-shifted views of the zero-padded batch → (B, Hb, W) conv response.

    views: tuple of ``kh`` arrays (B, Hb, Wp), view ``di`` holding rows
    ``di .. di+Hb`` of the padded batch (``Wp >= width_out + kw - 1``).
    taps: (kh, kw) nested tuples of static Python int coefficients.
    product_fn: ``fn(tile, c, table)`` — elementwise approximate product of
    an int32 tile with the static coefficient ``c``; ``table`` is the flat
    (2^{2N},) product LUT when given (Pallas forbids captured array
    constants, so table-driven strategies receive it as a kernel input) and
    None otherwise. Hb must be a multiple of ``block_h`` (the ops wrapper
    pads).
    """
    kh = len(taps)
    assert len(views) == kh, (len(views), kh)
    b, hb, wp = views[0].shape
    grid = (b, hb // block_h)
    view_spec = pl.BlockSpec((1, block_h, wp), lambda bb, i: (bb, i, 0))
    in_specs = [view_spec] * kh
    inputs = list(views)
    if table is not None:
        in_specs.append(pl.BlockSpec((table.shape[0],), lambda bb, i: (0,)))
        inputs.append(table)
    return pl.pallas_call(
        functools.partial(_fused_kernel, taps=taps, width_out=width_out,
                          product_fn=product_fn, has_table=table is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_h, width_out),
                               lambda bb, i: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hb, width_out), jnp.int32),
        interpret=interpret,
    )(*inputs)
