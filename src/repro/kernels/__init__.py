"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper, padding, interpret-mode selection via
``blocking.resolve_interpret``) and ref.py (pure-jnp oracle used by the
bit-identity test sweeps).

* ``closed_form`` — the proposed design's hand-derived closed form plus
  :func:`~repro.kernels.closed_form.make_closed_form`, which generates the
  vectorized closed form for *every* CSP wiring × width 3..16 from
  ``core.multiplier``'s slot taps.
* ``approx_mul`` / ``approx_matmul`` — elementwise and tiled-matmul
  kernels over a pluggable closed-form product model (vectorized
  ``k_chunk`` k-slab walk).
* ``lut_matmul`` — matmul fallback for product models with no CSP
  structure: the scalar product is a gather into a flat (2^N · 2^N,)
  product table, enumerable at widths 3..8.
* ``fused_conv`` — batched 'same' conv with im2col *inside* the kernel
  (row-shifted padded views, per-distinct-coefficient product maps); the
  fast path behind ``nn.conv.conv2d_batched`` for Pallas substrates.
  Absorbs the retired single-image ``laplacian_conv`` (its oracle lives on
  as ``fused_conv.ref.laplacian_conv_ref``).
"""
