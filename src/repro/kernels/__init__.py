"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper, padding, interpret fallback off-TPU) and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
