"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper, padding, interpret fallback off-TPU) and
ref.py (pure-jnp oracle used by the allclose test sweeps).

* ``approx_mul`` / ``approx_matmul`` / ``laplacian_conv`` — the proposed
  8-bit multiplier's closed form (elementwise, matmul, 3×3 conv).
* ``lut_matmul`` — wiring/width-generic matmul: the scalar product is a
  gather into a flat (2^N · 2^N,) product table, so every wiring in
  ``core.multiplier.ALL_MULTIPLIERS`` at widths 3..8 is TPU-runnable.
"""
