"""Jit'd public wrapper for the elementwise approximate-multiply kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blocking
from repro.kernels.approx_mul.kernel import approx_mul_pallas


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def approx_mul(a, b, block_m: int = 256, block_n: int = 128):
    """Elementwise approximate product of two equal-shape int arrays.

    Accepts any shape; internally flattens to 2-D, pads to block multiples
    (padding contributions are sliced away), and dispatches the Pallas kernel.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    shape = a.shape
    flat = a.reshape(-1)
    n_el = flat.shape[0]
    bn = block_n
    rows = -(-n_el // bn)
    bm = min(block_m, max(1, rows))
    pad_rows = (-rows) % bm
    total = (rows + pad_rows) * bn
    a2 = jnp.pad(flat, (0, total - n_el)).reshape(rows + pad_rows, bn)
    b2 = jnp.pad(b.reshape(-1), (0, total - n_el)).reshape(rows + pad_rows, bn)
    out = approx_mul_pallas(a2, b2, block_m=bm, block_n=bn,
                            interpret=blocking.resolve_interpret())
    return out.reshape(-1)[:n_el].reshape(shape)
