"""Elementwise approximate-multiply Pallas kernel.

The simplest hardware analogue: an array of the paper's multipliers. Inputs
are int8-domain values held in int32 (TPU VPU lanes are 32-bit); tiles are
(block_m, block_n) VMEM blocks, last dim aligned to the 128-lane VPU.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl

from repro.kernels.closed_form import approx_product_i32


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = approx_product_i32(a_ref[...], b_ref[...])


def approx_mul_pallas(a, b, *, block_m: int = 256, block_n: int = 128,
                      interpret: bool = False):
    """Elementwise proposed approximate product of two int32 arrays.

    a, b: (M, N) int32 in [-128, 127]; returns (M, N) int32.
    M % block_m == 0 and N % block_n == 0 (ops.py pads).
    """
    m, n = a.shape
    grid = (m // block_m, n // block_n)
    spec = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)
