"""Pure-jnp oracle for the elementwise approximate-multiply kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import multiplier as mult


def approx_mul_ref(a, b):
    """Elementwise proposed approximate product (core-library model)."""
    return mult.approx_multiply(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32))
