"""Shared plumbing for the Pallas kernel wrappers.

Two concerns, one home, so the kernel paths cannot silently diverge:

* pad-to-block / crop / f(0,0)-correct for the matmul kernels
  (``approx_matmul/ops.py``, ``lut_matmul/ops.py``): clamp the requested
  block sizes to TPU-tileable minima, zero-pad every dim up, crop the
  result, and subtract the multiplier's f(0,0) per padded k element
  (approximate wirings map (0,0) to a nonzero compensation value, so
  k-padding injects spurious contributions);
* interpret-mode selection (:func:`resolve_interpret`): one policy —
  explicit param beats the ``REPRO_PALLAS_INTERPRET`` env override beats
  the backend default — consumed by every ops wrapper instead of
  per-module ``_INTERPRET`` flags.
"""
from __future__ import annotations

import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp

# TPU int32 tile: the second-to-last dim aligns to 8 sublanes, the last to
# 128 lanes — block clamps for small shapes round up to these.
SUBLANE, LANE = 8, 128
_SUBLANE, _LANE = SUBLANE, LANE  # historical (pre-public) names

#: env var forcing Pallas interpret mode on ("1"/"true"/...) or off.
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Should a Pallas kernel run in interpret mode?

    Precedence: an explicit ``interpret`` argument wins; otherwise the
    ``REPRO_PALLAS_INTERPRET`` env var (``1/true/yes/on`` vs
    ``0/false/no/off``); otherwise interpret everywhere except on real TPU.
    The ops wrappers call this at trace time, so inside a jitted wrapper
    the decision is baked into the first trace for a given shape —
    set the env var before the first kernel call, not between calls.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        v = env.strip().lower()
        if v in _TRUTHY:
            return True
        if v in _FALSY:
            return False
        raise ValueError(
            f"{INTERPRET_ENV}={env!r} is neither truthy {_TRUTHY} nor "
            f"falsy {_FALSY}")
    return jax.default_backend() != "tpu"


def ceil_to(x: int, mult: int) -> int:
    """Round ``x`` up to a positive multiple of ``mult``."""
    return max(mult, ((x + mult - 1) // mult) * mult) if x > 0 else mult


def check_kernel_shapes(kernel_name: str, ops_name: str, a_shape, b_shape,
                        block_m: int, block_n: int, block_k: int) -> None:
    """Loud shape contract for the raw (block-multiple-only) kernels.

    Raises on a contraction-dim mismatch or any non-block-multiple dim —
    the raw kernels would otherwise silently compute garbage; the ops
    wrappers pad arbitrary shapes and correct the f(0,0) padding artifact.
    """
    m, k = a_shape
    k2, n = b_shape
    if k != k2:
        raise ValueError(
            f"contraction-dim mismatch: a is {tuple(a_shape)}, "
            f"b is {tuple(b_shape)}")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"{kernel_name} requires every dim to be a multiple of its "
            f"block size: got (M, K, N)=({m}, {k}, {n}) with blocks "
            f"(block_m, block_k, block_n)=({block_m}, {block_k}, {block_n})."
            f" Call {ops_name}, which pads and corrects the f(0,0) padding "
            "artifact.")


def pad_crop_correct(a, b, f00, kernel_call: Callable, *, block_m: int,
                     block_n: int, block_k: int):
    """Run a block-multiple-only matmul kernel on arbitrary (M,K)@(K,N).

    ``kernel_call(ap, bp, bm, bn, bk)`` receives the padded operands and the
    clamped block sizes; ``f00`` is the scalar-product model's value at
    (0, 0) (python int or traced scalar) used to correct the k-padding.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = min(block_m, ceil_to(m, _SUBLANE))
    bn = min(block_n, ceil_to(n, _LANE))
    bk = min(block_k, ceil_to(k, _SUBLANE))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    out = kernel_call(ap, bp, bm, bn, bk)[:m, :n]
    if pk:
        out = out - f00 * pk
    return out
