"""Jit'd public wrapper for the approximate matmul kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.approx_matmul.kernel import approx_matmul_pallas

_INTERPRET = jax.default_backend() != "tpu"

_F00 = 192  # f(0,0) of the proposed multiplier (compensation constant)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def approx_matmul(a, b, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """(M,K) @ (K,N) under the proposed approximate multiplier.

    Pads every dim to its block multiple. Zero-padding the contraction dim
    injects f(0,0)=192 per padded k element (the compensation constant fires
    on zero operands — faithful to the netlist), which is subtracted back.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    bk = min(block_k, _ceil_to(k, 8))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    out = approx_matmul_pallas(
        ap, bp, block_m=bm, block_n=bn, block_k=bk, interpret=_INTERPRET
    )
    out = out[:m, :n]
    if pk:
        out = out - _F00 * pk
    return out


def _ceil_to(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult) if x > 0 else mult
