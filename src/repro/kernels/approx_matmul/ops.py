"""Jit'd public wrapper for the approximate matmul kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.kernels import blocking
from repro.kernels.approx_matmul.kernel import approx_matmul_pallas

_INTERPRET = jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=None)
def _f00() -> int:
    """f(0,0) of the proposed multiplier, looked up from its product table.

    Shared with ``kernels/lut_matmul`` through ``core.lut.f00`` — the value
    is per-wiring/per-width (192 only for proposed@8), so a hard-coded
    constant here would silently miscompute the moment any other wiring
    reached this kernel.
    """
    return lut_lib.f00("proposed")


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def approx_matmul(a, b, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """(M,K) @ (K,N) under the proposed approximate multiplier.

    Pads every dim to its block multiple. Zero-padding the contraction dim
    injects f(0,0)=192 per padded k element (the compensation constant fires
    on zero operands — faithful to the netlist), which is subtracted back.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    return blocking.pad_crop_correct(
        a, b, _f00(),
        lambda ap, bp, bm, bn, bk: approx_matmul_pallas(
            ap, bp, block_m=bm, block_n=bn, block_k=bk, interpret=_INTERPRET),
        block_m=block_m, block_n=block_n, block_k=block_k)
