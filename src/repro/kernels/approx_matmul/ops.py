"""Jit'd public wrappers for the approximate matmul kernel.

* :func:`approx_matmul` — the historical entry point: proposed@8 via the
  hand-derived closed form.
* :func:`closed_form_matmul` — any CSP wiring/width 3..8 via the generated
  closed form (``kernels.closed_form.make_closed_form``); this is what
  ``nn.substrate.PallasSubstrate`` dispatches to, so non-proposed wirings
  run pure VPU algebra instead of the LUT-gather kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.kernels import blocking
from repro.kernels.approx_matmul.kernel import approx_matmul_pallas
from repro.kernels.closed_form import closed_form_f00, make_closed_form
from repro.obs.trace import trace_span


@functools.lru_cache(maxsize=None)
def _f00() -> int:
    """f(0,0) of the proposed multiplier, looked up from its product table.

    Shared with ``kernels/lut_matmul`` through ``core.lut.f00`` — the value
    is per-wiring/per-width (192 only for proposed@8), so a hard-coded
    constant here would silently miscompute the moment any other wiring
    reached this kernel.
    """
    return lut_lib.f00("proposed")


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "block_k", "k_chunk"))
def _approx_matmul_jit(a, b, block_m, block_n, block_k, k_chunk):
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    return blocking.pad_crop_correct(
        a, b, _f00(),
        lambda ap, bp, bm, bn, bk: approx_matmul_pallas(
            ap, bp, block_m=bm, block_n=bn, block_k=bk, k_chunk=k_chunk,
            interpret=blocking.resolve_interpret()),
        block_m=block_m, block_n=block_n, block_k=block_k)


def approx_matmul(a, b, block_m: int = 128, block_n: int = 128,
                  block_k: int = 128, k_chunk: int = 8):
    """(M,K) @ (K,N) under the proposed approximate multiplier.

    Pads every dim to its block multiple. Zero-padding the contraction dim
    injects f(0,0)=192 per padded k element (the compensation constant fires
    on zero operands — faithful to the netlist), which is subtracted back.
    ``k_chunk=1`` recovers the pre-vectorization scalar k-walk (kept as the
    benchmark baseline).
    """
    (m, k), (_, n) = jnp.shape(a), jnp.shape(b)
    with trace_span("kernel.approx_matmul", "kernel", m=m, k=k, n=n):
        return _approx_matmul_jit(a, b, block_m, block_n, block_k, k_chunk)


@functools.lru_cache(maxsize=None)
def _closed_form_runner(key: str, block_m: int, block_n: int, block_k: int,
                        k_chunk: int):
    product_fn = make_closed_form(key)
    f00 = closed_form_f00(key)

    @jax.jit
    def run(a, b):
        a = jnp.asarray(a, jnp.int32)
        b = jnp.asarray(b, jnp.int32)
        return blocking.pad_crop_correct(
            a, b, f00,
            lambda ap, bp, bm, bn, bk: approx_matmul_pallas(
                ap, bp, product_fn=product_fn, block_m=bm, block_n=bn,
                block_k=bk, k_chunk=k_chunk,
                interpret=blocking.resolve_interpret()),
            block_m=block_m, block_n=block_n, block_k=block_k)

    return run


def closed_form_matmul(a, b, mult_key: str = "proposed", *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128, k_chunk: int = 8):
    """(M,K) @ (K,N) under any CSP wiring's *generated* closed form.

    ``mult_key``: ``"name[@N]"`` (aliases resolve). Same pad/crop/f(0,0)
    contract as :func:`approx_matmul`; the jitted runner is cached per
    (wiring, block sizes, k_chunk).
    """
    key = mult.canonical_key(mult_key)
    run = _closed_form_runner(key, block_m, block_n, block_k, k_chunk)
    (m, k), (_, n) = jnp.shape(a), jnp.shape(b)
    with trace_span("kernel.closed_form_matmul", "kernel", mult=key,
                    m=m, k=k, n=n):
        return run(a, b)
