"""Pure-jnp oracle for the approximate matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import multiplier as mult


def approx_matmul_ref(a, b):
    """sum_k f(a[m,k], b[k,n]) with f = proposed approximate multiplier.

    Materializes the (M, K, N) product tensor — oracle for small shapes only.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    prod = mult.approx_multiply(a[:, :, None], b[None, :, :])
    return prod.sum(axis=1).astype(jnp.int32)
