"""Tiled approximate int8 matmul Pallas kernel (vectorized k-slab).

TPU adaptation of the paper's MAC array: every scalar product is an
approximate-multiplier closed form (VPU integer ops); accumulation is exact
int32 (the paper's adder tree is exact). The product model is pluggable
(``product_fn``): the default is the proposed 8-bit design's hand-derived
closed form, and ``kernels.closed_form.make_closed_form`` generates the
same algebra for every other CSP wiring/width.

Tiling: grid (M/bm, N/bn, K/bk); the output block (bm, bn) is revisited
across the k dimension (TPU sequential grid) and accumulated in place. The
inner k-slab is walked in ``k_chunk``-wide vectorized slabs: each step
broadcasts a (bm, kc, 1) slice of A against a (1, kc, bn) slice of B and
reduces the kc axis — one whole-slab VPU evaluation instead of the
historical per-k rank-1 ``fori_loop`` (recoverable with ``k_chunk=1``,
which benchmarks keep as the baseline). The (bm, kc, bn) int32 working set
bounds VMEM: 512 KiB at the default 128×8×128 — a full 128-deep slab would
need 8 MiB, which is why the chunk walk exists.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocking
from repro.kernels.closed_form import approx_product_i32


def resolve_k_chunk(k_chunk: int, block_k: int) -> int:
    """Largest divisor of ``block_k`` not exceeding ``k_chunk`` (≥ 1)."""
    return max(1, math.gcd(int(k_chunk), int(block_k)))


def _matmul_kernel(a_ref, b_ref, o_ref, *, block_k: int, k_chunk: int,
                   product_fn):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)  # (bm, bk)
    b = b_ref[...].astype(jnp.int32)  # (bk, bn)

    def body(j, acc):
        a_s = jax.lax.dynamic_slice_in_dim(a, j * k_chunk, k_chunk, axis=1)
        b_s = jax.lax.dynamic_slice_in_dim(b, j * k_chunk, k_chunk, axis=0)
        prod = product_fn(a_s[:, :, None], b_s[None, :, :])  # (bm, kc, bn)
        return acc + prod.sum(axis=1)

    acc = jax.lax.fori_loop(0, block_k // k_chunk, body, jnp.zeros_like(o_ref))
    o_ref[...] += acc


def approx_matmul_pallas(a, b, *, product_fn=approx_product_i32,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, k_chunk: int = 8,
                         interpret: bool = False):
    """(M,K) @ (K,N) int-domain contraction under ``product_fn``.

    a: (M, K) int32 operands in the model's domain; b: (K, N) int32.
    Returns (M, N) int32. ``k_chunk`` is clamped to a divisor of the block
    (``k_chunk=1`` reproduces the historical scalar k-walk). All dims must
    be multiples of their block sizes — non-multiples raise instead of
    silently computing garbage (``ops.approx_matmul`` pads arbitrary
    shapes and corrects for the multiplier's f(0,0) padding artifact).
    """
    m, k = a.shape
    _, n = b.shape
    blocking.check_kernel_shapes(
        "approx_matmul_pallas", "kernels.approx_matmul.ops.approx_matmul",
        a.shape, b.shape, block_m, block_n, block_k)
    k_chunk = resolve_k_chunk(k_chunk, block_k)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, block_k=block_k, k_chunk=k_chunk,
                          product_fn=product_fn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b)
