"""Tiled approximate int8 matmul Pallas kernel.

TPU adaptation of the paper's MAC array: every scalar product is the
proposed approximate multiplier (closed form, VPU integer ops); accumulation
is exact int32 (the paper's adder tree is exact).

Tiling: grid (M/bm, N/bn, K/bk); the output block (bm, bn) is revisited
across the k dimension (TPU sequential grid) and accumulated in place. The
inner k-slab is walked with a fori_loop, broadcasting a (bm, 1) column of A
against a (1, bn) row of B — pure VPU work with a (bm, bn) int32 working set
that fits comfortably in VMEM (default tiles: 128×128×4B = 64 KiB out block
+ two operand tiles).

A beyond-paper `exact_dot` escape hatch computes the same tiling with the
MXU-style jnp.dot (used by benchmarks to compare VPU-approx vs MXU-exact
cost structure).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import blocking
from repro.kernels.closed_form import approx_product_i32


def _matmul_kernel(a_ref, b_ref, o_ref, *, block_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)  # (bm, bk)
    b = b_ref[...].astype(jnp.int32)  # (bk, bn)

    def body(kk, acc):
        a_col = jax.lax.dynamic_slice_in_dim(a, kk, 1, axis=1)  # (bm, 1)
        b_row = jax.lax.dynamic_slice_in_dim(b, kk, 1, axis=0)  # (1, bn)
        return acc + approx_product_i32(a_col, b_row)

    acc = jax.lax.fori_loop(0, block_k, body, jnp.zeros_like(o_ref))
    o_ref[...] += acc


def approx_matmul_pallas(a, b, *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """(M,K) @ (K,N) int8-domain contraction under the proposed multiplier.

    a: (M, K) int32 in [-128,127]; b: (K, N) int32. Returns (M, N) int32.
    All dims must be multiples of their block sizes — non-multiples raise
    instead of silently computing garbage (``ops.approx_matmul`` pads
    arbitrary shapes and corrects for the multiplier's f(0,0) padding
    artifact).
    """
    m, k = a.shape
    _, n = b.shape
    blocking.check_kernel_shapes(
        "approx_matmul_pallas", "kernels.approx_matmul.ops.approx_matmul",
        a.shape, b.shape, block_m, block_n, block_k)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b)
