"""Atomic, elastic, async checkpointing (no external deps).

Design (scaled down from multi-host practice, same invariants):

* **Atomicity** — a checkpoint directory is written under a ``.tmp`` name
  and ``os.rename``d into place only after every array and the metadata
  manifest are flushed; a crashed save can never be mistaken for a valid
  step. Restore always picks the newest *complete* step.
* **Elasticity** — arrays are saved with their tree paths in a flat npz per
  step; on restore they are ``jax.device_put`` with whatever sharding the
  *new* mesh prescribes, so a checkpoint taken on a 16×16 mesh restores
  onto 2×16×16 (or a single CPU device) unchanged — elastic rescaling.
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a daemon thread, overlapping I/O with the next train steps;
  ``wait()`` joins before the next save or shutdown.
* **Retention** — keeps the newest ``keep`` checkpoints, deleting older
  ones only after a newer one is complete.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

SEP = "/"

# numpy can't serialize ml_dtypes (bf16 etc.) through npz: bitcast to a
# same-width integer container and record the true dtype in the manifest.
_CONTAINER = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode(arr: np.ndarray):
    if arr.dtype.kind in "biufc":  # plain numpy dtypes pass through
        return arr, None
    width = arr.dtype.itemsize
    return arr.view(_CONTAINER[width]), str(arr.dtype)


def _decode(arr: np.ndarray, dtype_name):
    if dtype_name is None:
        return arr
    import ml_dtypes  # noqa: F401  (registers bf16 & friends with numpy)
    return arr.view(np.dtype(dtype_name))


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(template)
    treedef = leaves_with_paths[1]
    leaves = []
    for path, leaf in leaves_with_paths[0]:
        key = SEP.join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict] = None):
    """Synchronous atomic save of a pytree at a step."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    encoded, dtypes = {}, {}
    for k, v in flat.items():
        arr, dt = _encode(v)
        encoded[k] = arr
        if dt is not None:
            dtypes[k] = dt
    np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
    manifest = {"step": step, "n_arrays": len(flat), "dtypes": dtypes,
                "time": time.time(), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):  # complete checkpoints only
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def load_checkpoint(directory: str, template, step: Optional[int] = None,
                    shardings=None):
    """Restore the newest (or given) step into ``template``'s structure.

    shardings: optional matching tree of NamedSharding — arrays are placed
    with the *current* mesh layout (elastic restore).
    """
    steps = list_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:010d}")
    manifest_all = json.load(open(os.path.join(path, "manifest.json")))
    dtypes = manifest_all.get("dtypes", {})
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: _decode(z[k], dtypes.get(k)) for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree_util.tree_map(jax.device_put, tree)
    return tree, step, manifest_all.get("extra", {})


# ---------------------------------------------------------------------------
# substrate-plan bundles (plan.json + optional params) — the autotuner's
# loadable artifact; serving round-trips it (launch/serve.py --plan)
# ---------------------------------------------------------------------------


def save_plan_bundle(directory: str, plan, params=None,
                     extra: Optional[dict] = None) -> str:
    """Atomic write of a substrate-plan bundle directory.

    Layout: ``plan.json`` (the :class:`repro.nn.plan.SubstratePlan` schema),
    ``manifest.json`` (kind/version/extra + array dtypes), and — when
    ``params`` is given — ``arrays.npz`` with the flattened param tree
    (same encoding as checkpoints, so bf16 round-trips). Written under a
    ``.tmp`` name and renamed into place; an existing bundle at
    ``directory`` is replaced atomically.
    """
    from repro.nn import plan as plan_mod

    plan = plan_mod.as_plan(plan)
    directory = os.path.abspath(directory)
    os.makedirs(os.path.dirname(directory) or ".", exist_ok=True)
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "plan.json"), "w") as f:
        json.dump(plan.to_dict(), f, indent=2)
        f.write("\n")
    manifest = {"kind": "substrate-plan-bundle", "version": 1,
                "time": time.time(), "has_params": params is not None,
                "dtypes": {}, "extra": extra or {}}
    if params is not None:
        flat = _flatten(params)
        encoded = {}
        for k, v in flat.items():
            arr, dt = _encode(v)
            encoded[k] = arr
            if dt is not None:
                manifest["dtypes"][k] = dt
        manifest["n_arrays"] = len(flat)
        np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)
    return directory


def load_plan_bundle(directory: str, params_template=None):
    """Load a plan bundle → ``(plan, params, extra)``.

    ``params_template`` restores the saved arrays into its tree structure
    (``jax.device_put``, elastic like :func:`load_checkpoint`); without a
    template, ``params`` is the raw flat ``{path: np.ndarray}`` dict when
    the bundle carries arrays, else None.
    """
    from repro.nn import plan as plan_mod

    manifest_path = os.path.join(directory, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    if manifest.get("kind") != "substrate-plan-bundle":
        raise ValueError(
            f"{directory} is not a substrate-plan bundle "
            f"(kind={manifest.get('kind')!r})")
    plan = plan_mod.load_plan(os.path.join(directory, "plan.json"))
    params = None
    if manifest.get("has_params"):
        dtypes = manifest.get("dtypes", {})
        with np.load(os.path.join(directory, "arrays.npz")) as z:
            flat = {k: _decode(z[k], dtypes.get(k)) for k in z.files}
        if params_template is not None:
            params = _unflatten_into(params_template, flat)
            params = jax.tree_util.tree_map(jax.device_put, params)
        else:
            params = flat
    elif params_template is not None:
        raise ValueError(f"bundle {directory} carries no params to restore")
    return plan, params, manifest.get("extra", {})


class CheckpointManager:
    """Async save + retention + resume discovery."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host synchronously: device buffers may be donated
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        save_checkpoint(self.directory, step, tree, extra)
        self._gc()

    def latest_step(self) -> Optional[int]:
        steps = list_steps(self.directory)
        return steps[-1] if steps else None

    def restore(self, template, shardings=None, step: Optional[int] = None):
        return load_checkpoint(self.directory, template, step, shardings)

    def _gc(self):
        steps = list_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
