"""Fault-tolerant checkpointing + substrate-plan bundles."""
from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    load_plan_bundle,
    save_checkpoint,
    save_plan_bundle,
)
