"""Core library: the paper's approximate signed multiplier, bit-exact in JAX.

Public API:
  compressors  — sign-focused compressor models (Table 2/3)
  multiplier   — closed-form + structural approximate BW multipliers
  metrics      — exhaustive ER/NMED/MRED evaluation (Table 4)
  lut          — 256×256 product tables (deployment artifact)
  energy       — unit-gate analytical hardware model (Table 5)
"""
from repro.core import compressors, energy, lut, metrics, multiplier  # noqa: F401

__all__ = ["compressors", "multiplier", "metrics", "lut", "energy"]
