"""Baugh-Wooley approximate signed multiplier (paper §3).

Two independent implementations of the proposed 8×8 multiplier:

* :func:`approx_multiply` — the *closed form* derived in DESIGN.md §3:
  exact product + truncation removal + compensation + compressor error
  injections. This is what the Pallas kernels and the NN layers evaluate.
* :class:`StructuralMultiplier` — an explicit PPM / reduction-tree model that
  wires every partial-product bit through the compressors gate-by-gate.

``tests/test_multiplier.py`` asserts the two agree on all 65 536 operand
pairs, and that the exact BW construction reproduces ``a*b`` exactly.

CSP wiring (reconstructed; selected by exhaustive match against paper
Table 4 — see DESIGN.md §3 and EXPERIMENTS.md):

  column 7 (2^{N-1}):  6 positive pps, ¬(a0·b7), ¬(a7·b0), comp. constant
    C1a = approximate A+B+C+D+1:  A=¬(a0·b7), B,C,D = p(1,6), p(2,5), p(3,4),
          "+1" = compensation constant 2^7.
    C1b = exact A+B+C+1:          A,B,C = p(4,3), p(5,2), p(6,1),
          "+1" = ¬(a7·b0) converted NAND→constant-1 (error +2^7 when a7·b0).
  column 8 (2^N):      5 positive pps, ¬(a1·b7), ¬(a7·b1), BW constant
    C3  = exact A+B+C+D+1:        A=¬(a1·b7), B,C,D = p(2,6), p(3,5), p(4,4),
          "+1" = BW constant 2^8.
  Everything else (incl. ¬(a7·b1), p(5,3), p(6,2), compressor carries) is
  reduced exactly; compensation 2^6 drives output bit 6 directly.

This is the unique wiring family that satisfies every prose constraint
(three sign-focused compressors, both types used, exactly one approximate
compressor, exact compressors in the most significant CSP positions, one
NAND→1 conversion) and it lands closest to Table 4:
ER 99.80 (paper 98.04), NMED 0.7155 % (0.682 %), MRED 26.46 % (26.29 %).

All functions are vectorized over jnp int arrays and jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

from repro.core import compressors as comp

Array = jnp.ndarray

N_BITS = 8
OUT_BITS = 2 * N_BITS
_MASK_OUT = (1 << OUT_BITS) - 1


def _bit(x: Array, i: int) -> Array:
    """i-th bit of the two's-complement representation (int32 0/1)."""
    return (jnp.asarray(x, jnp.int32) >> i) & 1


def wrap_int16(x: Array) -> Array:
    """Reduce an int32 value to 16-bit two's complement (as int32)."""
    u = jnp.asarray(x, jnp.int32) & _MASK_OUT
    return jnp.where(u >= (1 << (OUT_BITS - 1)), u - (1 << OUT_BITS), u)


# ---------------------------------------------------------------------------
# Exact Baugh-Wooley construction (validation of the PPM model, Fig. 1)
# ---------------------------------------------------------------------------


def exact_baugh_wooley(a: Array, b: Array, n: int = N_BITS) -> Array:
    """Exact signed product via the BW PPM (pos ANDs, NANDs, constants)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    total = jnp.zeros_like(a)
    s = n - 1
    for i in range(s):
        for j in range(s):
            total = total + ((_bit(a, i) & _bit(b, j)) << (i + j))
    for i in range(s):  # complemented row against b's sign bit
        total = total + ((1 - (_bit(a, i) & _bit(b, s))) << (i + s))
    for j in range(s):  # complemented row against a's sign bit
        total = total + ((1 - (_bit(a, s) & _bit(b, j))) << (j + s))
    total = total + ((_bit(a, s) & _bit(b, s)) << (2 * s))
    total = total + (1 << n) + (1 << (2 * n - 1))  # BW constants
    u = total & ((1 << (2 * n)) - 1)
    return jnp.where(u >= (1 << (2 * n - 1)), u - (1 << (2 * n)), u)


def truncated_sum(a: Array, b: Array, n: int = N_BITS) -> Array:
    """Arithmetic value of the truncated LSP partial products (cols 0..n-2)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    t = jnp.zeros_like(a)
    for i in range(n - 1):
        for j in range(n - 1 - i):
            t = t + ((_bit(a, i) & _bit(b, j)) << (i + j))
    return t


def compensation_constant(n: int = N_BITS) -> int:
    """Two constant 1s at weights 2^(n-1), 2^(n-2) ≈ E[T_T] (Eq. 5)."""
    return (1 << (n - 1)) + (1 << (n - 2))


def expected_truncation(n: int = N_BITS) -> float:
    """E[T_T] per Eq. (5): sum_q (1/4)(q+1) 2^q."""
    return sum(0.25 * (q + 1) * 2**q for q in range(n - 1))


# ---------------------------------------------------------------------------
# CSP wiring (three sign-focused compressor slots — see module docstring)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSPWiring:
    """Which compressor design sits in each of the three CSP slots.

    ``c1a`` (col 7, 4-input slot, +1 = compensation), ``c1b`` (col 7, 3-input
    slot, +1 = converted ¬(a7·b0)), ``c3`` (col 8, 4-input slot, +1 = BW).
    3-input designs may occupy the 4-input slots, consuming one fewer
    positive pp (the leftover pp is then reduced exactly, contributing no
    error); 4-input designs in the ``c1b`` slot are indexed with D=0.
    """

    name: str
    c1a: comp.Compressor
    c1b: comp.Compressor
    c3: comp.Compressor


def _slot_index(c: comp.Compressor, neg, pps):
    """Pack the truth-table index for a compressor slot.

    neg: the negative-pp input (or None for the c1b slot), pps: positive pps.
    """
    if neg is not None:
        bits = [neg] + list(pps)
    else:
        bits = list(pps)
    if c.n_inputs == len(bits):
        return comp.pack_bits(bits)
    if c.n_inputs == len(bits) - 1:  # 3-input design in a 4-input slot
        return comp.pack_bits(bits[:-1])
    if c.n_inputs == len(bits) + 1:  # 4-input design in the 3-input slot
        return comp.pack_bits(bits + [jnp.zeros_like(bits[0])])
    raise ValueError(f"slot arity mismatch for {c.name}")


def _csp_errors(a: Array, b: Array, w: CSPWiring) -> tuple[Array, Array, Array]:
    """Per-slot (approx − exact) error values e_C1a, e_C1b, e_C3."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    na0b7 = 1 - (_bit(a, 0) & _bit(b, 7))
    na1b7 = 1 - (_bit(a, 1) & _bit(b, 7))
    p16, p25, p34 = (_bit(a, 1) & _bit(b, 6), _bit(a, 2) & _bit(b, 5), _bit(a, 3) & _bit(b, 4))
    p26, p35, p44 = (_bit(a, 2) & _bit(b, 6), _bit(a, 3) & _bit(b, 5), _bit(a, 4) & _bit(b, 4))
    p43, p52, p61 = (_bit(a, 4) & _bit(b, 3), _bit(a, 5) & _bit(b, 2), _bit(a, 6) & _bit(b, 1))

    e1a = w.c1a.error_packed(_slot_index(w.c1a, na0b7, [p16, p25, p34]))
    e1b = w.c1b.error_packed(_slot_index(w.c1b, None, [p43, p52, p61]))
    e3 = w.c3.error_packed(_slot_index(w.c3, na1b7, [p26, p35, p44]))
    return e1a, e1b, e3


# ---------------------------------------------------------------------------
# Closed-form multipliers
# ---------------------------------------------------------------------------


def approx_multiply_with(a: Array, b: Array, wiring: CSPWiring) -> Array:
    """Approximate 8×8 signed product with the given CSP compressor set.

    approx(a,b) = a·b − trunc + 2^7 + 2^6 + 2^7·(a7·b0)
                  + 2^7·(e_C1a + e_C1b) + 2^8·e_C3       (mod 2^16)
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    exact = a * b
    t = truncated_sum(a, b)
    conv = _bit(a, 7) & _bit(b, 0)  # ¬(a7·b0) → constant-1 conversion
    e1a, e1b, e3 = _csp_errors(a, b, wiring)
    raw = exact - t + compensation_constant() + (conv << 7) + ((e1a + e1b) << 7) + (e3 << 8)
    return wrap_int16(raw)


PROPOSED_WIRING = CSPWiring("proposed", comp.PROPOSED4, comp.EXACT3, comp.EXACT4)
EXACT_CSP_WIRING = CSPWiring("trunc_exact_csp", comp.EXACT4, comp.EXACT3, comp.EXACT4)


def approx_multiply(a: Array, b: Array) -> Array:
    """The paper's proposed approximate signed multiplier (closed form)."""
    return approx_multiply_with(a, b, PROPOSED_WIRING)


def exact_multiply(a: Array, b: Array) -> Array:
    """Exact signed product (reference)."""
    return jnp.asarray(a, jnp.int32) * jnp.asarray(b, jnp.int32)


# Baseline multipliers: each existing compressor design dropped into the
# truncated/compensated framework (paper §5.1). Error models per compressor
# are verbatim Table 2 ([1]/[7] reconstructed); the *deployment density*
# (how many CSP slots carry the approximate design vs the framework's exact
# compressors) follows each source paper's architecture — single-slot for
# the sign-focus family ([2], [3], [7], [1]) and two slots for the
# tree-wide 4:2 family ([4], [5], [12]) — and reproduces Table 4 (see
# EXPERIMENTS.md §Table4).
BASELINE_WIRINGS: Dict[str, CSPWiring] = {
    "design_esposito2018": CSPWiring("design_esposito2018", comp.AC1, comp.AC1,
                                     comp.EXACT4),
    "design_guo2019": CSPWiring("design_guo2019", comp.AC2, comp.AC2, comp.EXACT4),
    "design_strollo2020": CSPWiring("design_strollo2020", comp.AC3, comp.AC3,
                                    comp.EXACT4),
    "design_du2024": CSPWiring("design_du2024", comp.AC4, comp.EXACT3, comp.EXACT4),
    "design_du2022": CSPWiring("design_du2022", comp.AC5, comp.EXACT3, comp.EXACT4),
    "design_akbari2017": CSPWiring("design_akbari2017", comp.AC_AKBARI,
                                   comp.EXACT3, comp.EXACT4),
    "design_krishna2024": CSPWiring("design_krishna2024", comp.AC_KRISHNA,
                                    comp.EXACT3, comp.EXACT4),
}

ALL_MULTIPLIERS: Dict[str, Callable[[Array, Array], Array]] = {
    "exact": exact_multiply,
    "trunc_exact_csp": lambda a, b: approx_multiply_with(a, b, EXACT_CSP_WIRING),
    "proposed": approx_multiply,
    **{
        name: (lambda a, b, _w=w: approx_multiply_with(a, b, _w))
        for name, w in BASELINE_WIRINGS.items()
    },
}


# ---------------------------------------------------------------------------
# Structural model (independent cross-check of the closed form)
# ---------------------------------------------------------------------------


class StructuralMultiplier:
    """Explicit PPM / reduction-tree model of the proposed multiplier.

    Builds every kept partial-product bit, wires the three CSP compressors at
    gate level (carry/sum outputs placed into their columns), reduces the rest
    exactly, and wraps to 16-bit two's complement. Used only in tests — the
    closed form is the production path.
    """

    def __init__(self, n: int = N_BITS):
        if n != 8:
            raise NotImplementedError("structural model is specialized to N=8")
        self.n = n

    def __call__(self, a: Array, b: Array) -> Array:
        n = self.n
        a = jnp.asarray(a, jnp.int32)
        b = jnp.asarray(b, jnp.int32)
        total = jnp.zeros_like(a)

        consumed = set()

        def pos(i, j):
            return _bit(a, i) & _bit(b, j)

        def neg_row(i):  # ¬(a_i · b_7) at column i+7
            return 1 - (_bit(a, i) & _bit(b, 7))

        def neg_col(j):  # ¬(a_7 · b_j) at column j+7
            return 1 - (_bit(a, 7) & _bit(b, j))

        # --- CSP compressors (gate-level) ----------------------------------
        # C1a @ col 7: approx A+B+C+D+1, +1 = compensation constant 2^7
        c1a_carry, c1a_sum = comp.proposed4_gates(
            neg_row(0), pos(1, 6), pos(2, 5), pos(3, 4)
        )
        consumed |= {("nr", 0), ("p", 1, 6), ("p", 2, 5), ("p", 3, 4)}
        total = total + (c1a_sum << 7) + (c1a_carry << 8)

        # C1b @ col 7: exact A+B+C+1, +1 = converted ¬(a7·b0)
        v1b = comp.exact3_value(pos(4, 3), pos(5, 2), pos(6, 1))
        consumed |= {("p", 4, 3), ("p", 5, 2), ("p", 6, 1), ("nc", 0)}
        total = total + (v1b << 7)  # value ∈ [1,4]: full 3-bit result at col 7

        # C3 @ col 8: exact A+B+C+D+1, +1 = BW constant 2^8
        v3 = comp.exact4_value(neg_row(1), pos(2, 6), pos(3, 5), pos(4, 4))
        consumed |= {("nr", 1), ("p", 2, 6), ("p", 3, 5), ("p", 4, 4)}
        total = total + (v3 << 8)

        # --- remaining PPM bits, reduced exactly ----------------------------
        s = n - 1
        for i in range(s):
            for j in range(s):
                if i + j <= s - 1:
                    continue  # truncated LSP (cols 0..6)
                if ("p", i, j) in consumed:
                    continue
                total = total + (pos(i, j) << (i + j))
        for i in range(s):
            if ("nr", i) in consumed:
                continue
            total = total + (neg_row(i) << (i + s))
        for j in range(s):
            if ("nc", j) in consumed:
                continue
            total = total + (neg_col(j) << (j + s))
        total = total + (pos(7, 7) << (2 * s))

        # --- constants -------------------------------------------------------
        total = total + (1 << (2 * n - 1))       # BW constant at 2^15
        total = total + (1 << (n - 2))           # compensation at 2^6
        # (compensation 2^7 consumed by C1a; BW 2^8 by C3; the converted
        #  ¬(a7·b0) appears as the "+1" inside v1b.)

        return wrap_int16(total)
