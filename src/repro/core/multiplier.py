"""Baugh-Wooley approximate signed multiplier, width-parametric (paper §3).

Two independent implementations of the proposed multiplier family, both
defined for arbitrary operand width ``n``:

* :func:`approx_multiply_with` — the *closed form* derived in DESIGN.md §3:
  exact product + truncation removal + compensation + compressor error
  injections. This is what the Pallas kernels and the NN layers evaluate.
* :class:`StructuralMultiplier` — an explicit PPM / reduction-tree model that
  wires every partial-product bit through the compressors slot-by-slot.

``tests/test_multiplier.py`` asserts the two agree on all 65 536 8-bit pairs;
``tests/test_widths.py`` extends the parity contract to N=4 (exhaustive) and
N=16 (sampled).

Width contract
==============

* Supported widths: ``MIN_BITS (3) <= n <= MAX_BITS (16)`` for the CSP
  wirings; :func:`exact_baugh_wooley` additionally accepts ``n == 2``. The
  ceiling exists because every model computes in int32 and the 2n-bit
  product of 16-bit operands exactly fills the int32 two's-complement ring.
* Operand range: signed n-bit two's complement, ``[-2^(n-1), 2^(n-1)-1]``.
  Out-of-range ints are **wrapped** into that range (low n bits,
  sign-extended) before the model is applied, so every backend — closed
  form, structural, LUT gather — agrees on arbitrary int inputs.
* Output: the 2n-bit two's-complement product value (wrapped via
  :func:`wrap_to_width`; for n=16 the int32 ring *is* the 32-bit wrap).
* Exhaustive verification: n=4 and n=8 are verified over the full operand
  grid in tests; n=16 is verified on random samples (the 2^32 grid is not
  enumerable in CI).

CSP wiring (reconstructed; selected by exhaustive match against paper
Table 4 — see DESIGN.md §3 and EXPERIMENTS.md). For n=8:

  column 7 (2^{N-1}):  6 positive pps, ¬(a0·b7), ¬(a7·b0), comp. constant
    C1a = approximate A+B+C+D+1:  A=¬(a0·b7), B,C,D = p(1,6), p(2,5), p(3,4),
          "+1" = compensation constant 2^7.
    C1b = exact A+B+C+1:          A,B,C = p(4,3), p(5,2), p(6,1),
          "+1" = ¬(a7·b0) converted NAND→constant-1 (error +2^7 when a7·b0).
  column 8 (2^N):      5 positive pps, ¬(a1·b7), ¬(a7·b1), BW constant
    C3  = exact A+B+C+D+1:        A=¬(a1·b7), B,C,D = p(2,6), p(3,5), p(4,4),
          "+1" = BW constant 2^8.
  Everything else (incl. ¬(a7·b1), p(5,3), p(6,2), compressor carries) is
  reduced exactly; compensation 2^6 drives output bit 6 directly.

For general n the same three slots sit at columns n-1 / n-1 / n; the slot
taps are the width-n analogues p(i, n-1-i) for i in 1..3 (C1a), 4..6 (C1b)
and p(i, n-i) for i in 2..4 (C3), clipped to the taps that exist at that
width (missing taps are fed as constant 0; surplus column bits are reduced
exactly and contribute no error). See ``docs/compressors.md`` for the
truncation/compensation math at general n.

This is the unique wiring family that satisfies every prose constraint
(three sign-focused compressors, both types used, exactly one approximate
compressor, exact compressors in the most significant CSP positions, one
NAND→1 conversion) and it lands closest to Table 4:
ER 99.80 (paper 98.04), NMED 0.7155 % (0.682 %), MRED 26.46 % (26.29 %).

All functions are vectorized over jnp int arrays and jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp

from repro.core import compressors as comp

Array = jnp.ndarray

N_BITS = 8
OUT_BITS = 2 * N_BITS
_MASK_OUT = (1 << OUT_BITS) - 1

MIN_BITS = 3   # below this the CSP columns degenerate to nothing
MAX_BITS = 16  # 2n-bit products must fit the int32 two's-complement ring

# Convenience wiring-family aliases: ``csp_axcK`` selects the CSP framework
# with approximate compressor design AC-K (Table 2 numbering) in its
# sign-focused slots — the names the cross-width sweeps use.
WIRING_ALIASES: Dict[str, str] = {
    "csp_axc1": "design_esposito2018",
    "csp_axc2": "design_guo2019",
    "csp_axc3": "design_strollo2020",
    "csp_axc4": "design_du2024",
    "csp_axc5": "design_du2022",
    "csp_akbari": "design_akbari2017",
    "csp_krishna": "design_krishna2024",
}


def _require_width(n: int) -> None:
    if not (MIN_BITS <= n <= MAX_BITS):
        raise ValueError(
            f"operand width must be in [{MIN_BITS}, {MAX_BITS}] (int32 models"
            f" cannot represent a {2 * n}-bit product ring); got n={n}")


def split_width(key: str, default: int = N_BITS) -> tuple[str, int]:
    """``"name[@N]"`` → (name, N). A bare name reads as the default width.

    The width must be a bare decimal integer — ``"@ 8"`` / ``"@+8"`` are
    rejected rather than silently normalized (``int()`` would accept both,
    turning a config typo into a well-formed key).
    """
    base, sep, w = str(key).partition("@")
    if not sep:
        return base, default
    if not (w.isascii() and w.isdigit()):
        raise ValueError(f"bad width suffix in multiplier key {key!r}")
    n = int(w)
    _require_width(n)
    return base, n


def canonical_key(key: str) -> str:
    """Resolve aliases and normalize the width suffix (``@8`` is implicit)."""
    base, n = split_width(key)
    base = WIRING_ALIASES.get(base, base)
    if base != "exact" and base not in WIRINGS:
        raise ValueError(f"unknown multiplier wiring: {base!r}")
    return base if n == N_BITS else f"{base}@{n}"


def _bit(x: Array, i: int) -> Array:
    """i-th bit of the two's-complement representation (int32 0/1)."""
    return (jnp.asarray(x, jnp.int32) >> i) & 1


def _const32(v: int) -> int:
    """Python constant → int32-representable value (mod 2^32); needed for
    the 2^31 Baugh-Wooley constant at n=16."""
    v &= (1 << 32) - 1
    return v - (1 << 32) if v >= (1 << 31) else v


def wrap_to_width(x: Array, out_bits: int) -> Array:
    """Reduce an int32 value to ``out_bits``-bit two's complement (int32).

    For ``out_bits >= 32`` this is the identity: int32 arithmetic already
    wraps mod 2^32, so the 32-bit product ring of n=16 operands is free.
    """
    x = jnp.asarray(x, jnp.int32)
    if out_bits >= 32:
        return x
    u = x & ((1 << out_bits) - 1)
    return jnp.where(u >= (1 << (out_bits - 1)), u - (1 << out_bits), u)


def wrap_int16(x: Array) -> Array:
    """Reduce an int32 value to 16-bit two's complement (as int32)."""
    return wrap_to_width(x, OUT_BITS)


def wrap_operand(x: Array, n: int = N_BITS) -> Array:
    """Wrap an int into the signed n-bit operand domain (low n bits)."""
    return wrap_to_width(x, n)


# ---------------------------------------------------------------------------
# Exact Baugh-Wooley construction (validation of the PPM model, Fig. 1)
# ---------------------------------------------------------------------------


def exact_baugh_wooley(a: Array, b: Array, n: int = N_BITS) -> Array:
    """Exact signed product via the BW PPM (pos ANDs, NANDs, constants)."""
    a = wrap_operand(jnp.asarray(a, jnp.int32), n)
    b = wrap_operand(jnp.asarray(b, jnp.int32), n)
    total = jnp.zeros_like(a)
    s = n - 1
    for i in range(s):
        for j in range(s):
            total = total + ((_bit(a, i) & _bit(b, j)) << (i + j))
    for i in range(s):  # complemented row against b's sign bit
        total = total + ((1 - (_bit(a, i) & _bit(b, s))) << (i + s))
    for j in range(s):  # complemented row against a's sign bit
        total = total + ((1 - (_bit(a, s) & _bit(b, j))) << (j + s))
    total = total + ((_bit(a, s) & _bit(b, s)) << (2 * s))
    total = total + _const32((1 << n) + (1 << (2 * n - 1)))  # BW constants
    return wrap_to_width(total, 2 * n)


def truncated_sum(a: Array, b: Array, n: int = N_BITS) -> Array:
    """Arithmetic value of the truncated LSP partial products (cols 0..n-2)."""
    a = wrap_operand(jnp.asarray(a, jnp.int32), n)
    b = wrap_operand(jnp.asarray(b, jnp.int32), n)
    t = jnp.zeros_like(a)
    for i in range(n - 1):
        for j in range(n - 1 - i):
            t = t + ((_bit(a, i) & _bit(b, j)) << (i + j))
    return t


def compensation_constant(n: int = N_BITS) -> int:
    """Constant 1s approximating E[T_T] (Eq. 5): ``(n-2) · 2^(n-3)``.

    This is exactly ``floor(E[T_T])`` at every width (the fractional part is
    always 0.25) and reproduces the paper's two constant 1s at weights
    2^(n-1), 2^(n-2) for n=8: 6·32 = 192 = 2^7 + 2^6. The binary expansion
    of (n-2) says which columns carry a compensation 1.
    """
    _require_width(n)
    return (n - 2) << (n - 3)


def expected_truncation(n: int = N_BITS) -> float:
    """E[T_T] per Eq. (5): sum_q (1/4)(q+1) 2^q = (n-2)·2^(n-3) + 1/4."""
    return sum(0.25 * (q + 1) * 2**q for q in range(n - 1))


# ---------------------------------------------------------------------------
# CSP wiring (three sign-focused compressor slots — see module docstring)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSPWiring:
    """Which compressor design sits in each of the three CSP slots.

    ``c1a`` (col n-1, 4-input slot, +1 = compensation), ``c1b`` (col n-1,
    3-input slot, +1 = converted ¬(a_{n-1}·b_0)), ``c3`` (col n, 4-input
    slot, +1 = BW constant). 3-input designs may occupy the 4-input slots,
    consuming one fewer positive pp (the leftover pp is then reduced exactly,
    contributing no error); 4-input designs in the ``c1b`` slot are indexed
    with D=0, as are slots whose width-n column has fewer taps than the
    design has inputs (narrow widths).
    """

    name: str
    c1a: comp.Compressor
    c1b: comp.Compressor
    c3: comp.Compressor


def csp_slot_taps(n: int) -> tuple[list, list, list]:
    """Positive-pp (i, j) taps feeding each CSP slot at width n.

    Column n-1 holds p(i, n-1-i) for i in 1..n-2: C1a takes i ∈ {1,2,3},
    C1b takes i ∈ {4,5,6}. Column n holds p(i, n-i) for i in 2..n-2: C3
    takes i ∈ {2,3,4}. Taps beyond the column population (narrow n) simply
    don't exist; taps beyond these windows (wide n) are reduced exactly.

    Public: ``kernels.closed_form.make_closed_form`` generates its
    vectorized per-wiring kernels from these taps.
    """
    c1a = [(i, n - 1 - i) for i in range(1, min(4, n - 1))]
    c1b = [(i, n - 1 - i) for i in range(4, min(7, n - 1))]
    c3 = [(i, n - i) for i in range(2, min(5, n - 1))]
    return c1a, c1b, c3


_csp_slot_taps = csp_slot_taps  # historical (pre-public) name


def _slot_index(c: comp.Compressor, neg, pps, zero: Array):
    """Pack the truth-table index for a compressor slot.

    neg: the negative-pp input (or None for the c1b slot); pps: positive
    pps. The bit list is truncated to the design's arity (surplus taps are
    reduced exactly elsewhere) or zero-padded (narrow widths / 4-input
    designs in the 3-input slot).
    """
    bits = ([neg] if neg is not None else []) + list(pps)
    bits = bits[: c.n_inputs]
    while len(bits) < c.n_inputs:
        bits.append(zero)
    return comp.pack_bits(bits)


def _csp_errors(a: Array, b: Array, w: CSPWiring,
                n: int = N_BITS) -> tuple[Array, Array, Array]:
    """Per-slot (approx − exact) error values e_C1a, e_C1b, e_C3 at width n."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    zero = jnp.zeros_like(a)
    t1a, t1b, t3 = _csp_slot_taps(n)
    pp = lambda ij: _bit(a, ij[0]) & _bit(b, ij[1])  # noqa: E731

    neg0 = 1 - (_bit(a, 0) & _bit(b, n - 1))  # ¬(a0·b_{n-1})
    neg1 = 1 - (_bit(a, 1) & _bit(b, n - 1))  # ¬(a1·b_{n-1})
    e1a = w.c1a.error_packed(_slot_index(w.c1a, neg0, [pp(t) for t in t1a], zero))
    e1b = w.c1b.error_packed(_slot_index(w.c1b, None, [pp(t) for t in t1b], zero))
    e3 = w.c3.error_packed(_slot_index(w.c3, neg1, [pp(t) for t in t3], zero))
    return e1a, e1b, e3


# ---------------------------------------------------------------------------
# Closed-form multipliers
# ---------------------------------------------------------------------------


def approx_multiply_with(a: Array, b: Array, wiring: CSPWiring,
                         n: int = N_BITS) -> Array:
    """Approximate n×n signed product with the given CSP compressor set.

    approx(a,b) = a·b − trunc + comp_n + 2^{n-1}·(a_{n-1}·b_0)
                  + 2^{n-1}·(e_C1a + e_C1b) + 2^n·e_C3       (mod 2^{2n})
    """
    _require_width(n)
    a = wrap_operand(jnp.asarray(a, jnp.int32), n)
    b = wrap_operand(jnp.asarray(b, jnp.int32), n)
    exact = a * b
    t = truncated_sum(a, b, n)
    conv = _bit(a, n - 1) & _bit(b, 0)  # ¬(a_{n-1}·b_0) → constant-1 conversion
    e1a, e1b, e3 = _csp_errors(a, b, wiring, n)
    raw = (exact - t + compensation_constant(n) + (conv << (n - 1))
           + ((e1a + e1b) << (n - 1)) + (e3 << n))
    return wrap_to_width(raw, 2 * n)


PROPOSED_WIRING = CSPWiring("proposed", comp.PROPOSED4, comp.EXACT3, comp.EXACT4)
EXACT_CSP_WIRING = CSPWiring("trunc_exact_csp", comp.EXACT4, comp.EXACT3, comp.EXACT4)


def approx_multiply(a: Array, b: Array) -> Array:
    """The paper's proposed approximate signed multiplier (8-bit closed form)."""
    return approx_multiply_with(a, b, PROPOSED_WIRING)


def exact_multiply(a: Array, b: Array) -> Array:
    """Exact signed product (reference; width-agnostic)."""
    return jnp.asarray(a, jnp.int32) * jnp.asarray(b, jnp.int32)


# Baseline multipliers: each existing compressor design dropped into the
# truncated/compensated framework (paper §5.1). Error models per compressor
# are verbatim Table 2 ([1]/[7] reconstructed); the *deployment density*
# (how many CSP slots carry the approximate design vs the framework's exact
# compressors) follows each source paper's architecture — single-slot for
# the sign-focus family ([2], [3], [7], [1]) and two slots for the
# tree-wide 4:2 family ([4], [5], [12]) — and reproduces Table 4 (see
# EXPERIMENTS.md §Table4).
BASELINE_WIRINGS: Dict[str, CSPWiring] = {
    "design_esposito2018": CSPWiring("design_esposito2018", comp.AC1, comp.AC1,
                                     comp.EXACT4),
    "design_guo2019": CSPWiring("design_guo2019", comp.AC2, comp.AC2, comp.EXACT4),
    "design_strollo2020": CSPWiring("design_strollo2020", comp.AC3, comp.AC3,
                                    comp.EXACT4),
    "design_du2024": CSPWiring("design_du2024", comp.AC4, comp.EXACT3, comp.EXACT4),
    "design_du2022": CSPWiring("design_du2022", comp.AC5, comp.EXACT3, comp.EXACT4),
    "design_akbari2017": CSPWiring("design_akbari2017", comp.AC_AKBARI,
                                   comp.EXACT3, comp.EXACT4),
    "design_krishna2024": CSPWiring("design_krishna2024", comp.AC_KRISHNA,
                                    comp.EXACT3, comp.EXACT4),
}

# Every named CSP wiring (the proposed design, the all-exact ablation, and
# the literature baselines). Aliases in WIRING_ALIASES resolve onto these.
WIRINGS: Dict[str, CSPWiring] = {
    "proposed": PROPOSED_WIRING,
    "trunc_exact_csp": EXACT_CSP_WIRING,
    **BASELINE_WIRINGS,
}


def get_wiring(name: str) -> CSPWiring:
    """Resolve a wiring name (or ``csp_*`` alias) to its CSPWiring."""
    name = WIRING_ALIASES.get(name, name)
    try:
        return WIRINGS[name]
    except KeyError:
        raise ValueError(f"unknown multiplier wiring: {name!r}") from None


def make_multiplier(name: str, n: int = N_BITS) -> Callable[[Array, Array], Array]:
    """Width-n product callable for a wiring name (or ``"exact"``)."""
    if name == "exact":
        return exact_multiply
    w = get_wiring(name)
    _require_width(n)

    def fn(a: Array, b: Array, _w=w, _n=n) -> Array:
        return approx_multiply_with(a, b, _w, n=_n)

    fn.__name__ = f"{name}@{n}" if n != N_BITS else name
    return fn


def resolve_multiplier(key: str, n: int | None = None
                       ) -> tuple[str, Callable[[Array, Array], Array], int]:
    """``"name[@N]"`` (+ optional explicit width) → (canonical_key, fn, N).

    The canonical key resolves aliases and drops the implicit ``@8`` suffix;
    it is the cache key for the width-indexed LUTs.
    """
    base, kn = split_width(key)
    if not base:
        raise ValueError(
            f"malformed multiplier key {key!r}: a width needs a wiring name "
            "(name[@N]), e.g. 'proposed@4'")
    width = n if n is not None else kn
    base = WIRING_ALIASES.get(base, base)
    key_c = base if width == N_BITS else f"{base}@{width}"
    return key_c, make_multiplier(base, width), width


# All registered product models. Bare names are the 8-bit designs; ``@4`` /
# ``@16`` variants instantiate the same wiring at the other verified widths.
# ``"exact"`` is width-agnostic (plain int product).
ALL_MULTIPLIERS: Dict[str, Callable[[Array, Array], Array]] = {
    "exact": exact_multiply,
    **{name: make_multiplier(name) for name in WIRINGS},
    **{f"{name}@{w}": make_multiplier(name, w)
       for name in WIRINGS for w in (4, 16)},
}


def default_width_names() -> list[str]:
    """The 8-bit design names (the paper's sweep set, no @N variants)."""
    return [k for k in ALL_MULTIPLIERS if "@" not in k]


# ---------------------------------------------------------------------------
# Structural model (independent cross-check of the closed form)
# ---------------------------------------------------------------------------


class StructuralMultiplier:
    """Explicit PPM / reduction-tree model of a CSP-framework multiplier.

    Builds every kept partial-product bit at width n, places the three CSP
    compressors' output values into their columns (via the compressor truth
    tables — value = carry/sum/cout weighted into col/col+1/col+2), reduces
    the rest exactly, and wraps to 2n-bit two's complement. Used only in
    tests — the closed form is the production path. Structural bookkeeping
    of the "+1" inputs: C1a's +1 realizes the 2^(n-1) compensation bit,
    C1b's +1 the converted ¬(a_{n-1}·b_0) constant, C3's +1 the BW constant
    2^n; the remaining compensation (which is negative for n<6, where the
    C1a "+1" overshoots E[T_T] — a software-model artifact, wrapped mod
    2^{2n}) and the BW 2^{2n-1} constant are added directly.
    """

    def __init__(self, n: int = N_BITS, wiring: CSPWiring = PROPOSED_WIRING):
        _require_width(n)
        self.n = n
        self.wiring = wiring

    def __call__(self, a: Array, b: Array) -> Array:
        n, w = self.n, self.wiring
        s = n - 1
        a = wrap_operand(jnp.asarray(a, jnp.int32), n)
        b = wrap_operand(jnp.asarray(b, jnp.int32), n)
        zero = jnp.zeros_like(a)
        total = jnp.zeros_like(a)

        def pos(i, j):
            return _bit(a, i) & _bit(b, j)

        def neg_row(i):  # ¬(a_i · b_{n-1}) at column i+n-1
            return 1 - (_bit(a, i) & _bit(b, s))

        def neg_col(j):  # ¬(a_{n-1} · b_j) at column j+n-1
            return 1 - (_bit(a, s) & _bit(b, j))

        t1a, t1b, t3 = _csp_slot_taps(n)
        consumed = set()

        def feed(c, neg_bit, taps):
            """Truth-table value of a slot + consumed-tap bookkeeping."""
            bits = ([] if neg_bit is None else [neg_bit]) + [pos(i, j) for i, j in taps]
            n_fed = min(len(bits), c.n_inputs)
            fed_taps = taps[: n_fed - (0 if neg_bit is None else 1)]
            idx = _slot_index(c, neg_bit, [pos(i, j) for i, j in taps], zero)
            return c.apply_packed(idx), fed_taps

        # --- CSP compressors (truth-table level) ---------------------------
        # C1a @ col n-1: 4-input slot, +1 = compensation bit 2^(n-1)
        v1a, fed = feed(w.c1a, neg_row(0), t1a)
        consumed |= {("nr", 0)} | {("p", i, j) for i, j in fed}
        total = total + (v1a << (n - 1))

        # C1b @ col n-1: 3-input slot, +1 = converted ¬(a_{n-1}·b_0)
        v1b, fed = feed(w.c1b, None, t1b)
        consumed |= {("nc", 0)} | {("p", i, j) for i, j in fed}
        total = total + (v1b << (n - 1))

        # C3 @ col n: 4-input slot, +1 = BW constant 2^n
        v3, fed = feed(w.c3, neg_row(1), t3)
        consumed |= {("nr", 1)} | {("p", i, j) for i, j in fed}
        total = total + (v3 << n)

        # --- remaining PPM bits, reduced exactly ---------------------------
        for i in range(s):
            for j in range(s):
                if i + j <= s - 1:
                    continue  # truncated LSP (cols 0..n-2)
                if ("p", i, j) in consumed:
                    continue
                total = total + (pos(i, j) << (i + j))
        for i in range(s):
            if ("nr", i) in consumed:
                continue
            total = total + (neg_row(i) << (i + s))
        for j in range(s):
            if ("nc", j) in consumed:
                continue
            total = total + (neg_col(j) << (j + s))
        total = total + (pos(s, s) << (2 * s))

        # --- constants -----------------------------------------------------
        total = total + _const32(1 << (2 * n - 1))  # BW constant at 2^(2n-1)
        # compensation beyond the 2^(n-1) bit realized by C1a's "+1"
        total = total + (compensation_constant(n) - (1 << (n - 1)))
        # (BW 2^n consumed by C3's +1; the converted ¬(a_{n-1}·b_0) appears
        #  as the "+1" inside v1b.)

        return wrap_to_width(total, 2 * n)
