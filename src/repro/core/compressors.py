"""Sign-focused compressor models (paper §2.1, §3.1; Tables 2 & 3).

Every compressor is modeled two ways:

1. *Gate-level boolean form* (`carry_fn` / `sum_fn` over jnp int arrays holding
   0/1 bits) — the behavioural netlist.
2. *Truth-table form* (`values` array indexed by the packed input bits) — used
   for exhaustive validation and for the error-statistics math (P_E, E_mean).

Input conventions follow the paper: for the ``A+B+C+1`` family, input ``A`` is
the *negative* partial product (NAND-generated, P(A=1)=3/4) and ``B``/``C`` are
positive partial products (AND-generated, P=1/4 each). For ``A+B+C+D+1``, ``A``
is negative and ``B,C,D`` positive. ``P(err)`` weighting in the statistics uses
those operand distributions, matching Table 2/3 of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# Truth-table container
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A (possibly approximate) compressor computing ``sum(inputs) + 1``.

    Attributes:
      name: design identifier (e.g. ``proposed3``, ``ac5_du2022``).
      n_inputs: 3 for ``A+B+C+1``, 4 for ``A+B+C+D+1``.
      values: np.ndarray of shape (2**n_inputs,) — the *approximate* output
        value for each packed input ``(A<<n-1 | ... | C<<0)``.
      exact: np.ndarray — the exact value ``popcount(idx) + 1``.
      source: citation tag.
      reconstructed: True when the truth table is not verbatim from the paper
        (designs [1]/[7], which Tables 4/5 reference without truth tables).
    """

    name: str
    n_inputs: int
    values: np.ndarray
    source: str = ""
    reconstructed: bool = False

    @property
    def exact(self) -> np.ndarray:
        idx = np.arange(2 ** self.n_inputs)
        pop = np.array([bin(i).count("1") for i in idx])
        return pop + 1

    @property
    def errors(self) -> np.ndarray:
        """approx − exact, per packed input combination."""
        return self.values - self.exact

    def input_probs(self) -> np.ndarray:
        """P(input combo) with A negative (P(1)=3/4) and the rest positive (1/4)."""
        n = self.n_inputs
        probs = np.ones(2 ** n)
        for idx in range(2 ** n):
            for bit in range(n):
                is_one = (idx >> (n - 1 - bit)) & 1
                p_one = 0.75 if bit == 0 else 0.25  # bit 0 == input A (negative pp)
                probs[idx] *= p_one if is_one else (1.0 - p_one)
        return probs

    def error_probability(self) -> float:
        """P_E per Eq. (4)."""
        return float(self.input_probs()[self.errors != 0].sum())

    def mean_error(self) -> float:
        """E_mean per Eq. (4): sum_i P(err_i) * (S_exact - S_approx)."""
        return float((self.input_probs() * (self.exact - self.values)).sum())

    # -- vectorized evaluation ------------------------------------------------

    def apply_packed(self, idx: Array) -> Array:
        """Approximate value for packed input indices (jnp int array)."""
        table = jnp.asarray(self.values, dtype=jnp.int32)
        return table[idx]

    def error_packed(self, idx: Array) -> Array:
        """approx − exact for packed input indices (jnp int array)."""
        table = jnp.asarray(self.errors, dtype=jnp.int32)
        return table[idx]

    def carry_bit(self, idx: Array) -> Array:
        """Carry output bit (weight 2) of the approximate value.

        All approximate designs in the paper emit at most {carry, sum}
        (value ≤ 3); exact designs emit cout as well — use
        :func:`exact_bits` for those.
        """
        return (self.apply_packed(idx) >> 1) & 1

    def sum_bit(self, idx: Array) -> Array:
        return self.apply_packed(idx) & 1


def pack_bits(bits: Sequence[Array]) -> Array:
    """Pack bit arrays [A, B, C, (D)] into truth-table indices, A = MSB."""
    n = len(bits)
    idx = jnp.zeros_like(jnp.asarray(bits[0], dtype=jnp.int32))
    for k, b in enumerate(bits):
        idx = idx | (jnp.asarray(b, dtype=jnp.int32) << (n - 1 - k))
    return idx


# ---------------------------------------------------------------------------
# Gate-level boolean forms for the proposed designs (Fig. 4 reconstruction)
# ---------------------------------------------------------------------------


def proposed3_gates(a: Array, b: Array, c: Array) -> tuple[Array, Array]:
    """Proposed approximate A+B+C+1: carry = A|B|C, sum = ¬(A·¬B·¬C)."""
    a, b, c = (jnp.asarray(x, jnp.int32) for x in (a, b, c))
    carry = a | b | c
    s = 1 - (a & (1 - b) & (1 - c))
    return carry, s


def proposed4_gates(a: Array, b: Array, c: Array, d: Array) -> tuple[Array, Array]:
    """Proposed approximate A+B+C+D+1: carry = A|B|C|D, sum = ¬(A·¬B·¬C·¬D)."""
    a, b, c, d = (jnp.asarray(x, jnp.int32) for x in (a, b, c, d))
    carry = a | b | c | d
    s = 1 - (a & (1 - b) & (1 - c) & (1 - d))
    return carry, s


def exact3_value(a: Array, b: Array, c: Array) -> Array:
    """Exact A+B+C+1 (proposed exact sign-focused compressor, Fig 3a)."""
    return jnp.asarray(a, jnp.int32) + jnp.asarray(b, jnp.int32) + jnp.asarray(c, jnp.int32) + 1


def exact4_value(a: Array, b: Array, c: Array, d: Array) -> Array:
    """Exact A+B+C+D+1 (proposed exact sign-focused compressor, Fig 3b)."""
    return exact3_value(a, b, c) + jnp.asarray(d, jnp.int32)


# ---------------------------------------------------------------------------
# Truth tables (Table 2 of the paper, verbatim; packed index = A<<2|B<<1|C)
# ---------------------------------------------------------------------------

def _table(vals: Sequence[int]) -> np.ndarray:
    return np.asarray(vals, dtype=np.int64)


# exact values for reference:        A,B,C = 000 001 010 011 100 101 110 111
#                                    exact =  1   2   2   3   2   3   3   4
EXACT3 = Compressor("exact3", 3, _table([1, 2, 2, 3, 2, 3, 3, 4]), source="[2] exact / Fig 3a")

AC1 = Compressor("ac1_esposito2018", 3, _table([1, 2, 2, 2, 2, 2, 2, 2]), source="[4]")
AC2 = Compressor("ac2_guo2019", 3, _table([1, 1, 1, 3, 2, 3, 3, 2]), source="[5]")
AC3 = Compressor("ac3_strollo2020", 3, _table([1, 2, 2, 3, 1, 2, 2, 3]), source="[12] stacking")
AC4 = Compressor("ac4_du2024", 3, _table([3, 3, 3, 3, 2, 3, 3, 2]), source="[3]")
AC5 = Compressor("ac5_du2022", 3, _table([2, 2, 2, 2, 2, 3, 3, 3]), source="[2]")
PROPOSED3 = Compressor("proposed3", 3, _table([1, 3, 3, 3, 2, 3, 3, 3]), source="paper Fig 4a")

# Proposed A+B+C+D+1 (Table 3 reconstruction; see DESIGN.md §3).
#   packed index = A<<3 | B<<2 | C<<1 | D ; exact = popcount+1
_PROP4_VALUES = []
for _i in range(16):
    _a = (_i >> 3) & 1
    _rest = _i & 0b0111
    _carry = 1 if _i else 0
    _sum = 0 if (_a == 1 and _rest == 0) else 1
    _PROP4_VALUES.append(2 * _carry + _sum)
PROPOSED4 = Compressor("proposed4", 4, _table(_PROP4_VALUES), source="paper Fig 4b / Table 3")

EXACT4 = Compressor(
    "exact4", 4, _table([bin(i).count("1") + 1 for i in range(16)]), source="Fig 3b"
)

# ---------------------------------------------------------------------------
# Reconstructed 4:2-family baselines used in Tables 4/5 rows [1] and [7].
#
# The paper integrates the compressors of Akbari'17 [1] (dual-quality 4:2,
# approximate mode) and Krishna'24 [7] (probability-based approximate 4:2)
# into the same multiplier framework but gives no truth tables for them.
# We reconstruct plausible tables consistent with their published error
# characteristics and with the NMED/MRED ordering the paper reports
# (NMED: [7] 0.542 < proposed 0.682 < [2] 0.731 < [1] 0.738;
#  MRED: [2] 26.84 < [1] 29.02 < [7] 33.00). Flagged `reconstructed=True`.
# ---------------------------------------------------------------------------

# [1] dual-quality 4:2 in low-quality mode: carry = OR, sum = ¬parity —
# exact for ≤2 ones, −2 on 3-or-4-one combos (the dual-quality approximate
# path drops the second carry chain).
_AC_AKBARI_VALUES = []
for _i in range(16):
    _a, _b, _c, _d = (_i >> 3) & 1, (_i >> 2) & 1, (_i >> 1) & 1, _i & 1
    _carry = _a | _b | _c | _d
    _sum = 1 - (_a ^ _b ^ _c ^ _d)
    _AC_AKBARI_VALUES.append(2 * _carry + _sum)
AC_AKBARI = Compressor(
    "ac_akbari2017", 4, _table(_AC_AKBARI_VALUES), source="[1]", reconstructed=True
)

# [7] probability-based approximate 4:2: saturating 2-output compressor that
# assumes ≥1 input high (the probability-based trait: P(A=1)=3/4) — error +1
# on the all-zero combo, −1/−2 on ≥3-one combos.
_AC_KRISHNA_VALUES = []
for _i in range(16):
    _exact = bin(_i).count("1") + 1
    _v = min(_exact, 3)
    if _i == 0:
        _v = 2
    _AC_KRISHNA_VALUES.append(_v)
AC_KRISHNA = Compressor(
    "ac_krishna2024", 4, _table(_AC_KRISHNA_VALUES), source="[7]", reconstructed=True
)

ALL_3INPUT = {c.name: c for c in [EXACT3, AC1, AC2, AC3, AC4, AC5, PROPOSED3]}
ALL_4INPUT = {c.name: c for c in [EXACT4, PROPOSED4, AC_AKBARI, AC_KRISHNA]}
ALL = {**ALL_3INPUT, **ALL_4INPUT}

# Paper-reported statistics for validation (Table 2 bottom rows).
PAPER_TABLE2_STATS = {
    # name: (P_E, E_mean) as printed in the paper
    "ac1_esposito2018": (22 / 64, 25 / 64),
    "ac2_guo2019": (9 / 64, 12 / 64),
    "ac3_strollo2020": (48 / 64, 48 / 64),
    "ac4_du2024": (18 / 64, -18 / 64),
    "ac5_du2022": (13 / 64, -5 / 64),
    "proposed3": (9 / 64, -3 / 64),
}
