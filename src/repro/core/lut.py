"""Precomputed lookup tables for the approximate multipliers, width-indexed.

A (2^n)×(2^n) int32 table fully characterizes any n×n multiplier model. The
LUT is the deployment artifact for the ``approx_lut`` execution mode
(gathers on TPU/CPU) and the ground truth for kernel tests.

Width contract
==============

* Tables are keyed ``"{mult_name}[@{n}]"`` (``@8`` implicit, aliases
  resolved), e.g. ``build_lut("proposed")`` → 256×256,
  ``build_lut("csp_axc1@4")`` → 16×16. Exhaustive tables are built for
  n ≤ MAX_LUT_BITS (8); wider widths raise ``ValueError`` — use the
  ``approx_bitexact`` closed form there.
* Index convention: ``lut[a + 2^(n-1), b + 2^(n-1)] = mult(a, b)`` for
  signed a, b in ``[-2^(n-1), 2^(n-1)-1]``. The table width is recoverable
  from ``lut.shape``, so every consumer below is width-aware.
* Wraparound: :func:`lut_multiply` masks gather indices to n bits, so
  out-of-range ints hit the same wrapped entry the closed form computes —
  LUT == bitexact on *arbitrary* int inputs, not just in-range ones.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

MAX_LUT_BITS = 8  # 2^(2n) entries; beyond 8 bits the table is impractical


def _lut_width(table) -> int:
    """Operand width implied by a table's shape (inverse of build_lut)."""
    size = table.shape[0]
    n = size.bit_length() - 1
    if table.shape[-2:] != (1 << n, 1 << n):
        raise ValueError(f"not a product LUT shape: {table.shape}")
    return n


@functools.lru_cache(maxsize=None)
def _build_lut_canonical(key: str) -> np.ndarray:
    from repro.core import multiplier as m

    base, n = m.split_width(key)
    if n > MAX_LUT_BITS:
        raise ValueError(
            f"exhaustive LUTs are built for widths <= {MAX_LUT_BITS} "
            f"(got {key!r}: 2^{2 * n} entries); use the approx_bitexact "
            "closed form for wider operands")
    fn = m.make_multiplier(base, n)
    with jax.ensure_compile_time_eval():
        lo, hi = -(1 << (n - 1)), 1 << (n - 1)
        v = jnp.arange(lo, hi, dtype=jnp.int32)
        a, b = jnp.meshgrid(v, v, indexing="ij")
        table = fn(a.reshape(-1), b.reshape(-1)).reshape(1 << n, 1 << n)
    return np.asarray(table, dtype=np.int32)


def build_lut(mult_name: str) -> np.ndarray:
    """Build (and cache) the product table for ``"name[@N]"`` (N ≤ 8).

    Runs under ``ensure_compile_time_eval`` so the table stays concrete even
    when first requested inside an outer trace (e.g. lowering a model whose
    dot_mode consults the LUT). Aliases and the implicit ``@8`` width are
    canonicalized before caching, so ``"proposed"``, ``"proposed@8"`` and a
    spec-derived key share one table.
    """
    from repro.core import multiplier as m

    return _build_lut_canonical(m.canonical_key(mult_name))


def lut_multiply(a: Array, b: Array, lut: Array) -> Array:
    """Gather-based approximate product; width derives from ``lut.shape``.

    Indices are masked to the table's operand width, matching the closed
    form's operand-wraparound semantics for out-of-range ints.
    """
    lut = jnp.asarray(lut)
    n = _lut_width(lut)
    size, off = 1 << n, 1 << (n - 1)
    ai = (jnp.asarray(a, jnp.int32) + off) & (size - 1)
    bi = (jnp.asarray(b, jnp.int32) + off) & (size - 1)
    return lut[ai, bi]


def flat_lut(mult_name: str) -> np.ndarray:
    """Flat ``(2^{2n},)`` view of the product table for gather kernels.

    Entry layout matches the index the LUT Pallas kernel computes:
    ``flat[((a + off) & mask) << n | ((b + off) & mask)] = mult(a, b)``
    with ``off = 2^(n-1)``, ``mask = 2^n - 1`` — i.e. a row-major flatten
    of the 2-D table, so the 2-D and flat gathers hit identical entries.
    """
    return build_lut(mult_name).reshape(-1)


def f00(mult_name: str) -> int:
    """The model's product at (0, 0) — the k-padding correction constant.

    Approximate wirings map (0,0) to a nonzero value (the compensation
    constant fires regardless of operands), and that value differs across
    wirings and widths (e.g. proposed@8 → 192, design_strollo2020@8 → 64,
    proposed@4 → 4): any contraction that zero-pads the k dimension must
    subtract *this wiring's* f(0,0) per padded element, never a hard-coded
    constant. Shared by ``kernels/approx_matmul`` and ``kernels/lut_matmul``.
    """
    table = build_lut(mult_name)
    off = 1 << (_lut_width(table) - 1)
    return int(table[off, off])


def error_lut(mult_name: str) -> np.ndarray:
    """(2^n)×(2^n) table of (approx − exact) — compact error characterization."""
    table = build_lut(mult_name)
    n = _lut_width(table)
    lo, hi = -(1 << (n - 1)), 1 << (n - 1)
    v = np.arange(lo, hi, dtype=np.int64)
    exact = v[:, None] * v[None, :]
    return (table.astype(np.int64) - exact).astype(np.int32)


def error_moments(mult_name: str) -> dict:
    """Mean/std of the error under uniform operands — drives approx_stat mode.

    Normalization is over the table's own 2^(2n) entries (width-aware), not a
    hard-coded 256×256 — a 4-bit LUT's moments average over 256 pairs.
    """
    e = error_lut(mult_name).astype(np.float64)
    return dict(mean=float(e.mean()), std=float(e.std()), max_abs=float(np.abs(e).max()))
