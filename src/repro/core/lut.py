"""Precomputed lookup tables for the approximate multiplier.

A 256×256 int16 table fully characterizes any 8×8 multiplier model. The LUT
is the deployment artifact for the ``approx_lut`` execution mode (gathers on
TPU/CPU) and the ground truth for kernel tests. Index convention:
``lut[a + 128, b + 128] = mult(a, b)`` for signed a, b in [-128, 127].
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@functools.lru_cache(maxsize=None)
def build_lut(mult_name: str) -> np.ndarray:
    """Build (and cache) the 256×256 product table for a named multiplier.

    Runs under ``ensure_compile_time_eval`` so the table stays concrete even
    when first requested inside an outer trace (e.g. lowering a model whose
    dot_mode consults the LUT).
    """
    from repro.core import multiplier as m

    fn = m.ALL_MULTIPLIERS[mult_name]
    with jax.ensure_compile_time_eval():
        v = jnp.arange(-128, 128, dtype=jnp.int32)
        a, b = jnp.meshgrid(v, v, indexing="ij")
        table = fn(a.reshape(-1), b.reshape(-1)).reshape(256, 256)
    return np.asarray(table, dtype=np.int32)


def lut_multiply(a: Array, b: Array, lut: Array) -> Array:
    """Gather-based approximate product; a, b int arrays in [-128, 127]."""
    ai = (jnp.asarray(a, jnp.int32) + 128).astype(jnp.int32)
    bi = (jnp.asarray(b, jnp.int32) + 128).astype(jnp.int32)
    return jnp.asarray(lut)[ai, bi]


def error_lut(mult_name: str) -> np.ndarray:
    """256×256 table of (approx − exact) — compact error characterization."""
    v = np.arange(-128, 128, dtype=np.int64)
    exact = v[:, None] * v[None, :]
    return (build_lut(mult_name).astype(np.int64) - exact).astype(np.int32)


def error_moments(mult_name: str) -> dict:
    """Mean/std of the error under uniform operands — drives approx_stat mode."""
    e = error_lut(mult_name).astype(np.float64)
    return dict(mean=float(e.mean()), std=float(e.std()), max_abs=float(np.abs(e).max()))
