"""Analytical unit-gate hardware model (reproduces paper Table 5).

No EDA tools are available in this environment, so the paper's UMC-90nm
synthesis numbers are reproduced with a *unit-gate* model:

* every design is expanded into a gate inventory: partial-product gates, CSP
  compressor gates, a simulated Dadda-style reduction tree (full/half adders
  counted by actually running the column-reduction algorithm), and a final
  carry-propagate adder;
* per-gate area/delay/energy weights follow the standard unit-gate convention
  (NAND2 = 1 area / 1 delay; XOR = 2.5 / 2; INV = 0.5 / 0.5; ...);
* per-design *structure descriptors* encode how each source paper deploys its
  compressors (tree-wide 4:2 for [1]/[4]/[12]/[7], LSP truncation for
  [2]/proposed, dual-mode duplication for [1], the optimized 3:2 compressor
  of [8] in the proposed MSP);
* three global scale factors (area → µm², delay → ns, power → µW) are
  calibrated on the *exact* multiplier row of Table 5 only; every other row
  is then predicted.

The reproduction target is the *relative* savings (proposed vs [2]:
−14.39 % power, −29.21 % PDP); absolute µm²/µW for the six literature
baselines depend on architectural details in *their* papers and carry more
model error.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

# unit-gate weights: name -> (area_units, delay_units, energy_weight)
GATES = {
    "inv": (0.5, 0.5, 0.5),
    "nand2": (1.0, 1.0, 1.0),
    "nor2": (1.0, 1.0, 1.0),
    "and2": (1.5, 1.2, 1.5),
    "or2": (1.5, 1.2, 1.5),
    "or3": (2.0, 1.5, 2.0),
    "xor2": (2.5, 2.0, 3.0),
    "mux2": (2.5, 2.0, 2.5),
}

FULL_ADDER = {"xor2": 2, "and2": 2, "or2": 1}   # standard mirror FA
FA_OPT = {"xor2": 1, "nand2": 3, "mux2": 1}     # [8] optimized 3:2 compressor
HALF_ADDER = {"xor2": 1, "and2": 1}


def _block_cost(block: Dict[str, float]) -> tuple[float, float]:
    area = sum(GATES[k][0] * n for k, n in block.items())
    energy = sum(GATES[k][2] * n for k, n in block.items())
    return area, energy


@dataclasses.dataclass(frozen=True)
class DesignDescriptor:
    """Structure of one multiplier design (source-paper architecture)."""

    name: str
    lsp: str                    # 'exact' | 'truncate' | 'approx'
    csp_gates: Dict[str, float]  # the 3 CSP/sign-handling compressors
    tree_fa: Dict[str, int]      # FA cell used in the reduction tree
    approx_lsp_cell: Dict[str, float] | None = None  # per-LSP-column cell
    area_factor: float = 1.0    # [1]: duplicated exact+approx circuits + muxes
    energy_factor: float = 1.0  # gated idle paths draw less than their area share
    cpa_bits: int = 16
    extra_stage_delay: float = 0.0  # compressor critical path (delay units)


DESIGNS: Dict[str, DesignDescriptor] = {
    "exact": DesignDescriptor(
        "exact", lsp="exact", csp_gates={}, tree_fa=FULL_ADDER, cpa_bits=16
    ),
    # [4] Esposito'18: approximate 4:2 compressors through the lower tree
    "design_esposito2018": DesignDescriptor(
        "design_esposito2018", lsp="approx",
        csp_gates={"xor2": 2, "mux2": 2, "or2": 3, "and2": 2},
        tree_fa=FULL_ADDER, approx_lsp_cell={"or2": 2, "and2": 1},
        cpa_bits=14, extra_stage_delay=0.5,
    ),
    # [1] Akbari'17: dual-quality 4:2 — duplicated exact+approximate paths
    # (high area), approximate mode active with exact path clock-gated
    "design_akbari2017": DesignDescriptor(
        "design_akbari2017", lsp="approx",
        csp_gates={"xor2": 4, "mux2": 3, "or2": 4, "and2": 3},
        tree_fa=FULL_ADDER, approx_lsp_cell={"or2": 1.8, "and2": 1.2},
        area_factor=1.18, energy_factor=0.91,
        cpa_bits=14, extra_stage_delay=1.8,
    ),
    # [5] Guo'19: sign-focused compressors, partial truncation
    "design_guo2019": DesignDescriptor(
        "design_guo2019", lsp="approx",
        csp_gates={"xor2": 3, "and2": 4, "or2": 3, "inv": 2},
        tree_fa=FULL_ADDER, approx_lsp_cell={"or2": 1.5, "and2": 1},
        cpa_bits=12, extra_stage_delay=1.2,
    ),
    # [12] Strollo'20: stacking-logic 4:2 compressors tree-wide
    "design_strollo2020": DesignDescriptor(
        "design_strollo2020", lsp="approx",
        csp_gates={"and2": 4, "or2": 4, "inv": 3},
        tree_fa=FULL_ADDER, approx_lsp_cell={"or2": 2.2, "and2": 1.5},
        cpa_bits=14, extra_stage_delay=0.8,
    ),
    # [7] Krishna'24: probability-based approximate 4:2
    "design_krishna2024": DesignDescriptor(
        "design_krishna2024", lsp="approx",
        csp_gates={"or3": 2, "or2": 4, "nand2": 3, "inv": 3, "and2": 2},
        tree_fa=FULL_ADDER, approx_lsp_cell={"or2": 1.8, "and2": 1.2},
        cpa_bits=13, extra_stage_delay=0.9,
    ),
    # [2] Du'22: sign-focus compressor + truncation + error compensation
    "design_du2022": DesignDescriptor(
        "design_du2022", lsp="truncate",
        csp_gates={"xor2": 6, "or2": 5, "and2": 5, "inv": 3},
        tree_fa=FULL_ADDER, cpa_bits=11, extra_stage_delay=1.8,
    ),
    # proposed: truncation + (1 approx A+B+C+D+1, 1 exact A+B+C+1,
    # 1 exact A+B+C+D+1) + [8] optimized 3:2 in the MSP tree
    "proposed": DesignDescriptor(
        "proposed", lsp="truncate",
        csp_gates={"or3": 1, "or2": 5, "nand2": 1, "inv": 1, "xor2": 5, "and2": 5},
        tree_fa=FA_OPT, cpa_bits=9, extra_stage_delay=0.3,
    ),
    # ablation: truncated framework with all-exact CSP compressors
    "trunc_exact_csp": DesignDescriptor(
        "trunc_exact_csp", lsp="truncate",
        csp_gates={"xor2": 8, "and2": 7, "or2": 5, "mux2": 1},
        tree_fa=FA_OPT, cpa_bits=9, extra_stage_delay=0.6,
    ),
}


def reduce_columns(heights: List[int]) -> tuple[int, int, float]:
    """Simulate Dadda-style reduction to ≤2 rows; (n_fa, n_ha, stages)."""
    heights = list(heights)
    n_fa = n_ha = 0
    stages = 0
    while heights and max(heights) > 2:
        stages += 1
        new = [0] * (len(heights) + 1)
        for col, h in enumerate(heights):
            fa = h // 3
            rem = h - 3 * fa
            ha = 1 if rem == 2 and fa == 0 and h > 2 else 0
            n_fa += fa
            n_ha += ha
            new[col] += h - 2 * fa - ha
            new[col + 1] += fa + ha
        heights = new
        while heights and heights[-1] == 0:
            heights.pop()
    return n_fa, n_ha, float(stages)


def _exact_heights(n: int = 8) -> List[int]:
    s = n - 1
    h = [0] * (2 * n)
    for i in range(s):
        for j in range(s):
            h[i + j] += 1
    for i in range(s):
        h[i + s] += 1      # ¬(a_i b_{n-1})
    for j in range(s):
        h[j + s] += 1      # ¬(a_{n-1} b_j)
    h[2 * s] += 1          # a_{n-1} b_{n-1}
    h[n] += 1              # BW const
    h[2 * n - 1] += 1      # BW const
    return h


def _framework_heights(four_input: bool, n: int = 8) -> List[int]:
    """Truncated-framework heights after the three CSP compressors fire.

    Wiring per multiplier.py: col n-1 hosts C1a (4-input slot, +1=comp) and
    C1b (3-input slot, +1=converted ¬(a_{n-1}·b_0)); col n hosts C3
    (4-input slot, +1=BW const). Tap counts per slot come from the
    width-n slot assignment (narrow widths feed fewer bits).
    """
    from repro.core.multiplier import _csp_slot_taps, compensation_constant

    h = _exact_heights(n)
    for q in range(n - 1):
        h[q] = 0
    # compensation bits below 2^(n-1) drive output columns directly (the
    # 2^(n-1) bit is the C1a "+1"); none exist for n < 6
    rest = max(compensation_constant(n) - (1 << (n - 1)), 0)
    for q in range(rest.bit_length()):  # bits reach col n+1 for wide n
        if (rest >> q) & 1:
            h[q] += 1
    t1a, t1b, t3 = _csp_slot_taps(n)
    eat1a = 1 + min(len(t1a), (4 if four_input else 3) - 1)  # neg + taps fed
    eat1b = min(len(t1b), 3)
    eat3 = 1 + min(len(t3), (4 if four_input else 3) - 1)
    h[n - 1] = h[n - 1] - 1 - eat1a - eat1b + 2  # conversion + C1a + C1b, 2 sums back
    h[n] = h[n] - 1 - eat3 + 1 + 2               # C3 (+BW const), sum + 2 carries in
    h[n + 1] += 1                                # carry of C3
    return [max(0, x) for x in h]


_FOUR_INPUT = {"proposed", "trunc_exact_csp", "design_akbari2017", "design_krishna2024"}


@dataclasses.dataclass
class CostBreakdown:
    area_units: float
    energy_units: float
    delay_units: float


def multiplier_cost(design: str, n: int = 8) -> CostBreakdown:
    """Unit-gate cost of a design instantiated at operand width n.

    Descriptors are calibrated at n=8 (the paper's width); at other widths
    the partial-product array, reduction tree, and CPA scale with n while
    the three CSP compressors stay fixed-size — the cross-width numbers are
    unit-gate extrapolations for the error-vs-energy sweeps, not synthesis.
    """
    d = DESIGNS[design]
    s = n - 1
    area = energy = 0.0

    # partial-product gates: (n-1)^2 + 1 ANDs, 2(n-1) NANDs
    n_pp_and, n_pp_nand = s * s + 1, 2 * s
    if d.lsp == "truncate":
        n_pp_and -= n * s // 2   # LSP columns 0..n-2 dropped
        n_pp_nand -= 1           # one NAND converted to a constant
    a, e = _block_cost({"and2": n_pp_and, "nand2": n_pp_nand})
    area += a
    energy += e

    # CSP / sign-handling compressors (three slots at every width)
    a, e = _block_cost(d.csp_gates)
    area += a
    energy += e

    # reduction tree
    if d.lsp == "truncate":
        heights = _framework_heights(design in _FOUR_INPUT, n)
    else:
        heights = _exact_heights(n)
        if d.lsp == "approx":
            # LSP columns reduced by cheap approximate cells instead of FAs
            lsp_bits = sum(heights[:s])
            a, e = _block_cost({k: v * (lsp_bits / 3) for k, v in d.approx_lsp_cell.items()})
            area += a
            energy += e
            for q in range(s):
                heights[q] = min(heights[q], 2)
    n_fa, n_ha, stages = reduce_columns(heights)
    fa_area, fa_energy = _block_cost(d.tree_fa)
    ha_area, ha_energy = _block_cost(HALF_ADDER)
    area += n_fa * fa_area + n_ha * ha_area
    energy += n_fa * fa_energy + n_ha * ha_energy

    # final carry-propagate adder (descriptor bits are for n=8; scale with n)
    cpa_bits = max(2, round(d.cpa_bits * n / 8))
    a, e = _block_cost({k: v * cpa_bits for k, v in FULL_ADDER.items()})
    area += a
    energy += e

    area *= d.area_factor
    energy *= d.energy_factor

    t_fa = GATES["xor2"][1] * (2 if d.tree_fa is FULL_ADDER else 1.6)
    t_cpa = GATES["and2"][1] + GATES["or2"][1]
    delay = GATES["and2"][1] + d.extra_stage_delay + stages * t_fa + cpa_bits * t_cpa
    return CostBreakdown(area, energy, delay)


# calibration targets: the exact row of Table 5
_PAPER_EXACT = dict(area=2204.75, power=178.10, delay=3.28)

PAPER_TABLE5 = {
    "exact": dict(area=2204.75, power=178.10, delay=3.28, pdp=584.17),
    "design_esposito2018": dict(area=1242.07, power=136.95, delay=2.17, pdp=297.41),
    "design_akbari2017": dict(area=1972.91, power=122.19, delay=2.65, pdp=324.08),
    "design_guo2019": dict(area=1164.34, power=116.05, delay=2.49, pdp=289.15),
    "design_strollo2020": dict(area=1386.62, power=129.96, delay=2.32, pdp=302.48),
    "design_krishna2024": dict(area=1306.84, power=124.89, delay=2.35, pdp=293.95),
    "design_du2022": dict(area=1013.07, power=110.42, delay=2.54, pdp=280.48),
    "proposed": dict(area=809.23, power=94.52, delay=2.10, pdp=198.54),
}


def estimate(design: str, n: int = 8) -> Dict[str, float]:
    """Predicted area (µm²), power (µW), delay (ns), PDP (fJ) for a design.

    Scale factors are calibrated on the exact 8-bit row of Table 5 at every
    width, so cross-width numbers share one unit→physical mapping.
    """
    ref = multiplier_cost("exact")
    s_area = _PAPER_EXACT["area"] / ref.area_units
    s_delay = _PAPER_EXACT["delay"] / ref.delay_units
    s_power = _PAPER_EXACT["power"] / ref.energy_units
    c = multiplier_cost(design, n)
    area = c.area_units * s_area
    delay = c.delay_units * s_delay
    power = c.energy_units * s_power
    return dict(area=area, power=power, delay=delay, pdp=power * delay)


def table5() -> Dict[str, Dict[str, float]]:
    return {d: estimate(d) for d in DESIGNS if d != "trunc_exact_csp"}


def savings_vs(design: str, baseline: str) -> Dict[str, float]:
    d, b = estimate(design), estimate(baseline)
    return {k: 100.0 * (1.0 - d[k] / b[k]) for k in ("area", "power", "delay", "pdp")}
