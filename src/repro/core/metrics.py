"""Error metrics for approximate multipliers (paper §5.1, Eq. 7–8).

All metrics are computed *exhaustively* over the full 8-bit signed operand
space (65 536 pairs) unless a subset is passed. MRED excludes pairs whose
exact product is zero (relative error undefined there); the exclusion is
511/65536 pairs and is the standard convention.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray
MultFn = Callable[[Array, Array], Array]


@dataclasses.dataclass(frozen=True)
class ErrorReport:
    name: str
    er: float        # error rate: P(approx != exact)
    med: float       # mean |error distance|
    nmed: float      # MED / max|exact|
    mred: float      # mean relative error distance (exact != 0)
    max_ed: int      # max |error distance|
    mean_err: float  # signed mean error (bias)

    def row(self) -> str:
        return (
            f"{self.name:>22s}  ER={self.er * 100:6.2f}%  NMED={self.nmed * 100:6.4f}%  "
            f"MRED={self.mred * 100:6.2f}%  MED={self.med:8.2f}  bias={self.mean_err:+8.2f}"
        )


def operand_grid(n_bits: int = 8) -> tuple[Array, Array]:
    """All (a, b) signed pairs as flat arrays."""
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1))
    v = jnp.arange(lo, hi, dtype=jnp.int32)
    a, b = jnp.meshgrid(v, v, indexing="ij")
    return a.reshape(-1), b.reshape(-1)


@jax.jit
def _exact_products(a: Array, b: Array) -> Array:
    return a * b


def evaluate(mult_fn: MultFn, name: str = "", n_bits: int = 8) -> ErrorReport:
    """Exhaustive ER / MED / NMED / MRED for an 8×8 multiplier model."""
    a, b = operand_grid(n_bits)
    exact = np.asarray(_exact_products(a, b), dtype=np.int64)
    approx = np.asarray(jax.jit(mult_fn)(a, b), dtype=np.int64)
    err = approx - exact
    abs_err = np.abs(err)
    nz = exact != 0
    max_exact = np.abs(exact).max()
    return ErrorReport(
        name=name or getattr(mult_fn, "__name__", "multiplier"),
        er=float((err != 0).mean()),
        med=float(abs_err.mean()),
        nmed=float(abs_err.mean() / max_exact),
        mred=float((abs_err[nz] / np.abs(exact[nz])).mean()),
        max_ed=int(abs_err.max()),
        mean_err=float(err.mean()),
    )


def evaluate_all(mult_fns: Dict[str, MultFn], n_bits: int = 8) -> Dict[str, ErrorReport]:
    return {name: evaluate(fn, name, n_bits) for name, fn in mult_fns.items()}


# Paper Table 4 values (percent), for validation bands in tests/benchmarks.
PAPER_TABLE4 = {
    "design_strollo2020": dict(er=98.47, nmed=1.128, mred=32.80),
    "design_guo2019": dict(er=98.95, nmed=0.829, mred=30.00),
    "design_esposito2018": dict(er=99.42, nmed=0.786, mred=35.25),
    "design_akbari2017": dict(er=97.37, nmed=0.738, mred=29.02),
    "design_krishna2024": dict(er=98.95, nmed=0.542, mred=33.00),
    "design_du2022": dict(er=98.15, nmed=0.731, mred=26.84),
    "proposed": dict(er=98.04, nmed=0.682, mred=26.29),
}
