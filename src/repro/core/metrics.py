"""Error metrics for approximate multipliers (paper §5.1, Eq. 7–8).

All metrics are computed *exhaustively* over the full n-bit signed operand
space (65 536 pairs at the default n=8) via :func:`evaluate`; widths whose
grid is not enumerable (n > MAX_EXHAUSTIVE_BITS) use :func:`evaluate_sampled`
on a seeded uniform operand sample. MRED excludes pairs whose exact product
is zero (relative error undefined there); the exclusion is 511/65536 pairs
at n=8 and is the standard convention.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray
MultFn = Callable[[Array, Array], Array]


@dataclasses.dataclass(frozen=True)
class ErrorReport:
    name: str
    er: float        # error rate: P(approx != exact)
    med: float       # mean |error distance|
    nmed: float      # MED / max|exact|
    mred: float      # mean relative error distance (exact != 0)
    max_ed: int      # max |error distance|
    mean_err: float  # signed mean error (bias)

    def row(self) -> str:
        return (
            f"{self.name:>22s}  ER={self.er * 100:6.2f}%  NMED={self.nmed * 100:6.4f}%  "
            f"MRED={self.mred * 100:6.2f}%  MED={self.med:8.2f}  bias={self.mean_err:+8.2f}"
        )


MAX_EXHAUSTIVE_BITS = 12  # 2^(2n) pairs; beyond this use evaluate_sampled


def operand_grid(n_bits: int = 8) -> tuple[Array, Array]:
    """All (a, b) signed pairs as flat arrays (n_bits ≤ MAX_EXHAUSTIVE_BITS)."""
    if n_bits > MAX_EXHAUSTIVE_BITS:
        raise ValueError(
            f"exhaustive grid at n={n_bits} has 2^{2 * n_bits} pairs; "
            "use sample_operands/evaluate_sampled for wide operands")
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1))
    v = jnp.arange(lo, hi, dtype=jnp.int32)
    a, b = jnp.meshgrid(v, v, indexing="ij")
    return a.reshape(-1), b.reshape(-1)


def sample_operands(n_bits: int = 16, n_samples: int = 1 << 16,
                    seed: int = 0) -> tuple[Array, Array]:
    """Seeded uniform (a, b) operand sample for non-enumerable widths."""
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (n_bits - 1)), (1 << (n_bits - 1))
    a = rng.integers(lo, hi, n_samples, dtype=np.int64).astype(np.int32)
    b = rng.integers(lo, hi, n_samples, dtype=np.int64).astype(np.int32)
    return jnp.asarray(a), jnp.asarray(b)


@jax.jit
def _exact_products(a: Array, b: Array) -> Array:
    return a * b


def _report(name: str, exact: np.ndarray, approx: np.ndarray) -> ErrorReport:
    err = approx - exact
    abs_err = np.abs(err)
    nz = exact != 0
    max_exact = np.abs(exact).max()
    return ErrorReport(
        name=name,
        er=float((err != 0).mean()),
        med=float(abs_err.mean()),
        nmed=float(abs_err.mean() / max_exact),
        mred=float((abs_err[nz] / np.abs(exact[nz])).mean()),
        max_ed=int(abs_err.max()),
        mean_err=float(err.mean()),
    )


def evaluate(mult_fn: MultFn, name: str = "", n_bits: int = 8) -> ErrorReport:
    """Exhaustive ER / MED / NMED / MRED for an n×n multiplier model."""
    a, b = operand_grid(n_bits)
    exact = np.asarray(_exact_products(a, b), dtype=np.int64)
    approx = np.asarray(jax.jit(mult_fn)(a, b), dtype=np.int64)
    return _report(name or getattr(mult_fn, "__name__", "multiplier"),
                   exact, approx)


def evaluate_sampled(mult_fn: MultFn, name: str = "", n_bits: int = 16,
                     n_samples: int = 1 << 16, seed: int = 0) -> ErrorReport:
    """Sampled error metrics for widths whose grid is not enumerable (n=16)."""
    a, b = sample_operands(n_bits, n_samples, seed)
    exact = np.asarray(_exact_products(a, b), dtype=np.int64)
    approx = np.asarray(jax.jit(mult_fn)(a, b), dtype=np.int64)
    return _report(name or getattr(mult_fn, "__name__", "multiplier"),
                   exact, approx)


def evaluate_all(mult_fns: Dict[str, MultFn], n_bits: int = 8) -> Dict[str, ErrorReport]:
    return {name: evaluate(fn, name, n_bits) for name, fn in mult_fns.items()}


# Paper Table 4 values (percent), for validation bands in tests/benchmarks.
PAPER_TABLE4 = {
    "design_strollo2020": dict(er=98.47, nmed=1.128, mred=32.80),
    "design_guo2019": dict(er=98.95, nmed=0.829, mred=30.00),
    "design_esposito2018": dict(er=99.42, nmed=0.786, mred=35.25),
    "design_akbari2017": dict(er=97.37, nmed=0.738, mred=29.02),
    "design_krishna2024": dict(er=98.95, nmed=0.542, mred=33.00),
    "design_du2022": dict(er=98.15, nmed=0.731, mred=26.84),
    "proposed": dict(er=98.04, nmed=0.682, mred=26.29),
}
