"""Multi-device behaviour, run in subprocesses with 8 fake host devices
(conftest must NOT set the device-count flag globally — smoke tests and
benches see 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8, extra_env: dict | None = None) -> str:
    """Run a code snippet in a subprocess with N forced host devices.

    Shared harness — ``tests/test_dot_general.py`` reuses it for the
    sharded-contraction parity suite. ``extra_env`` overlays the
    environment (e.g. interpret-mode toggles).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compressed_dp_step_matches_uncompressed():
    """int8-compressed gradient all-reduce ≈ exact pmean on 8 devices."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.train.loop import dp_train_step_compressed
        from repro.optim import adamw

        def loss_fn(params, batch):
            pred = batch["tokens"].astype(jnp.float32) @ params["w"]
            tgt = batch["labels"].astype(jnp.float32)
            return jnp.mean((pred - tgt[..., None]) ** 2)

        mesh = jax.make_mesh((8,), ("data",))
        params = {"w": jnp.ones((16, 1), jnp.float32) * 0.1}
        opt = adamw(weight_decay=0.0)
        state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32),
                 "labels": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        with mesh:
            f_c = dp_train_step_compressed(loss_fn, opt, mesh, compress=True)
            f_u = dp_train_step_compressed(loss_fn, opt, mesh, compress=False)
            lc, pc, _ = f_c(params, state, batch, jnp.float32(1e-2))
            lu, pu, _ = f_u(params, state, batch, jnp.float32(1e-2))
        err = float(jnp.abs(pc["w"] - pu["w"]).max())
        print("loss", float(lc), float(lu), "err", err)
        assert abs(float(lc) - float(lu)) < 1e-5
        assert err < 1e-3, err
    """)
    assert "err" in out


def test_dryrun_cell_on_debug_mesh():
    """lower+compile a reduced arch on a 4x2 mesh; roofline terms emitted."""
    out = run_py("""
        import jax, jax.numpy as jnp, json
        from repro.launch import mesh as mesh_lib, roofline
        from repro.models import registry as reg
        from repro.optim import adamw

        cfg = reg.get_config("minitron-8b", n_layers=2, d_model=128, d_ff=256,
                             vocab=512, n_heads=4, n_kv_heads=2,
                             attn_chunk=64, loss_chunk=64, remat=False)
        bundle = reg._BUILDERS[cfg.family](cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = adamw()
        with mesh:
            params_sds = reg.param_specs(bundle)
            p_sh = mesh_lib.param_shardings(params_sds, mesh)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            o_sh = mesh_lib.param_shardings(opt_sds, mesh)
            batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            b_sh = mesh_lib.batch_shardings(batch, mesh)
            def step(p, o, b):
                loss, grads = jax.value_and_grad(bundle.loss_fn)(p, b)
                np_, no_ = opt.update(grads, o, p, lr=jnp.float32(1e-3))
                return loss, np_, no_
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                params_sds, opt_sds, batch)
            compiled = lowered.compile()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
        rf = roofline.derive(cost, hlo, 8, roofline.model_flops_for(
            cfg, reg.SHAPES["train_4k"]))
        stats = roofline.parse_collectives(hlo)
        print(json.dumps({"flops": rf.flops_per_device,
                          "coll": stats.total_bytes,
                          "bottleneck": rf.bottleneck}))
        assert rf.flops_per_device > 0
        assert stats.total_bytes > 0  # sharded training must communicate
    """)
    assert "bottleneck" in out


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save params sharded on a (4,2) mesh; restore onto (2,4) and 1-device."""
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, load_checkpoint

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        save_checkpoint({str(tmp_path)!r}, 1, {{"w": wa}})

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        tgt = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
        tree, step, _ = load_checkpoint({str(tmp_path)!r}, {{"w": w}}, shardings=sh)
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(w))
        tree2, _, _ = load_checkpoint({str(tmp_path)!r}, {{"w": w}})
        np.testing.assert_array_equal(np.asarray(tree2["w"]), np.asarray(w))
        print("elastic ok", tree["w"].sharding)
    """)
    assert "elastic ok" in out


def test_sharding_rules_shard_big_leaves():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import mesh as mesh_lib
        from repro.models import registry as reg
        mesh = mesh_lib.make_production_mesh(multi_pod=True)
        assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
        cfg = reg.get_config("kimi-k2-1t-a32b")
        bundle = reg._BUILDERS[cfg.family](cfg)
        sds = reg.param_specs(bundle)
        sh = mesh_lib.param_shardings(sds, mesh)
        # the expert weight must be sharded on expert AND fsdp axes
        leaves = jax.tree_util.tree_flatten_with_path(sh)[0]
        import numpy as np
        total, mx = 0, 0
        for path, s in leaves:
            leaf = jax.tree_util.tree_flatten_with_path(sds)[0]
        flat_sds = {tuple(str(getattr(e,'key',getattr(e,'idx',e))) for e in p): l
                    for p, l in jax.tree_util.tree_flatten_with_path(sds)[0]}
        flat_sh = {tuple(str(getattr(e,'key',getattr(e,'idx',e))) for e in p): s
                   for p, s in jax.tree_util.tree_flatten_with_path(sh)[0]}
        worst = 0
        for k, l in flat_sds.items():
            n_shards = 1
            spec = flat_sh[k].spec
            for dim, d in enumerate(spec):
                if d is None: continue
                names = d if isinstance(d, tuple) else (d,)
                import math
                prod = math.prod(mesh.shape[n] for n in names)
                n_shards *= prod
            per_dev = np.prod(l.shape) * l.dtype.itemsize / n_shards
            worst = max(worst, per_dev)
        print("worst per-device leaf bytes:", worst/2**30, "GiB")
        assert worst < 8 * 2**30, worst  # largest leaf < 8 GiB/device
    """, n_devices=512)
    assert "worst" in out


def test_sharded_edge_detect_matches_unsharded():
    """edge_detect_batched under a Partitioning (serving mesh path) is
    bit-identical to the unsharded path on 8 devices."""
    out = run_py("""
        import jax, numpy as np
        from repro.data import image_batch
        from repro.launch import mesh as mesh_lib
        from repro.nn import conv

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        part = mesh_lib.contraction_partitioning(mesh)
        imgs = image_batch(4, 24, 24)
        for spec in ("approx_bitexact", "approx_lut:design_strollo2020"):
            ref = np.asarray(conv.edge_detect_batched(imgs, spec))
            got = np.asarray(
                conv.edge_detect_batched(imgs, spec, partitioning=part))
            np.testing.assert_array_equal(got, ref, err_msg=spec)
        print("sharded edge ok", part.m_shards, part.k_shards)
    """)
    assert "sharded edge ok 4 2" in out


def test_dryrun_partitioned_approx_substrate_lowers():
    """--dot-partition mesh path: an approx substrate (approx_stat) lowers
    and compiles on a debug mesh with every dense() through shard_map."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import mesh as mesh_lib
        from repro.models import registry as reg
        from repro.nn import substrate as psub

        cfg = reg.get_config("minitron-8b", n_layers=2, d_model=128, d_ff=256,
                             vocab=512, n_heads=4, n_kv_heads=2,
                             attn_chunk=64, loss_chunk=64, remat=False,
                             dot_mode="approx_stat")
        bundle = reg._BUILDERS[cfg.family](cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        part = mesh_lib.contraction_partitioning(mesh)
        assert (part.m_axis, part.k_axis) == ("data", "model")
        with mesh, psub.partitioning_scope(part):
            params_sds = reg.param_specs(bundle)
            p_sh = mesh_lib.param_shardings(params_sds, mesh)
            batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            b_sh = mesh_lib.batch_shardings(batch, mesh)
            compiled = jax.jit(bundle.loss_fn,
                               in_shardings=(p_sh, b_sh)).lower(
                params_sds, batch).compile()
        assert "psum" in compiled.as_text() or \
            "all-reduce" in compiled.as_text()
        print("partitioned lowering ok")
    """)
    assert "partitioned lowering ok" in out
