"""ProductSubstrate registry: cross-backend parity, batched conv/edge
detection against the single-image loop, and end-to-end model dispatch
(including the Pallas kernel in interpret mode)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multiplier as mult
from repro.data import image_batch
from repro.models import registry as reg
from repro.nn import conv
from repro.nn import substrate as sub

RNG = np.random.default_rng(11)

ALL_BACKENDS = {"exact", "int8", "approx_bitexact", "approx_lut",
                "approx_stat", "approx_pallas"}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_all_backends():
    assert set(sub.list_substrates()) == ALL_BACKENDS


def test_spec_parsing_and_mult_reachability():
    s = sub.get_substrate("approx_lut:design_du2022")
    assert s.meta.name == "approx_lut" and s.meta.mult_name == "design_du2022"
    # explicit mult_name overrides the suffix
    s2 = sub.get_substrate("approx_lut:design_du2022", mult_name="proposed")
    assert s2.meta.mult_name == "proposed"
    # every entry in ALL_MULTIPLIERS (incl. @4/@16 variants) is reachable
    # through the bitexact backend; LUT covers the enumerable widths
    for name in mult.ALL_MULTIPLIERS:
        base, width = mult.split_width(name)
        s3 = sub.get_substrate("approx_bitexact", mult_name=name)
        assert (s3.meta.mult_name, s3.meta.width) == (base, width)
        assert s3.meta.mult_key == (name if width != 8 else base)
        if width <= 8:
            assert sub.get_substrate("approx_lut", mult_name=name).meta.width == width


def test_unknown_backend_and_wiring_raise():
    with pytest.raises(ValueError, match="unknown product substrate"):
        sub.get_substrate("systolic")
    with pytest.raises(ValueError, match="unknown multiplier wiring"):
        sub.get_substrate("approx_lut:not_a_design")
    with pytest.raises(ValueError, match="unknown multiplier wiring"):
        sub.get_substrate("approx_pallas:not_a_design")


def test_exact_backends_reject_wiring_suffix():
    """A wiring on an exact backend is a confused spec, not a no-op."""
    for spec in ("int8:design_du2022", "exact:proposed"):
        with pytest.raises(ValueError, match="takes no multiplier wiring"):
            sub.get_substrate(spec)


def test_meta_label_distinguishes_wirings():
    assert sub.get_substrate("approx_lut").meta.label == "approx_lut"
    assert sub.get_substrate("approx_lut:design_du2022").meta.label \
        == "approx_lut:design_du2022"


def test_get_substrate_is_cached():
    assert sub.get_substrate("approx_lut") is sub.get_substrate("approx_lut")


# ---------------------------------------------------------------------------
# integer-contraction parity: pallas == lut == bitexact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mkn", [
    (1, 1, 1),          # degenerate
    (5, 19, 3),         # K not a multiple of the k-chunk / pallas block
    (16, 32, 8),
    (33, 100, 17),      # every dim off the pallas block grid
    (8, 128, 4),        # K exactly one pallas block
])
def test_pallas_lut_bitexact_parity(mkn):
    """The f(0,0)=192 padding correction must make all three bit-exact
    backends agree on arbitrary (incl. non-block-multiple-K) shapes."""
    m, k, n = mkn
    a8 = RNG.integers(-128, 128, (m, k)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    outs = {name: np.asarray(sub.get_substrate(name).dot_int8(a8, b8))
            for name in ("approx_bitexact", "approx_lut", "approx_pallas")}
    np.testing.assert_array_equal(outs["approx_bitexact"], outs["approx_lut"])
    np.testing.assert_array_equal(outs["approx_bitexact"], outs["approx_pallas"])


def test_scalar_faithful_dot_matches_scalar_sum():
    """dot_int8 == Σ_k scalar(a_k, b_k) for every scalar-faithful substrate."""
    a8 = RNG.integers(-128, 128, (4, 11)).astype(np.int64)
    b8 = RNG.integers(-128, 128, (11, 3)).astype(np.int64)
    for spec in sub.list_substrates():
        s = sub.get_substrate(spec)
        if not s.meta.scalar_faithful:
            continue
        oracle = np.asarray(
            s.scalar(jnp.asarray(a8[:, :, None], jnp.int32),
                     jnp.asarray(b8[None, :, :], jnp.int32))).sum(axis=1)
        got = np.asarray(s.dot_int8(a8.astype(np.int8), b8.astype(np.int8)))
        np.testing.assert_array_equal(got, oracle, err_msg=spec)


# ---------------------------------------------------------------------------
# batched conv parity vs the single-image loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", sorted(ALL_BACKENDS))
def test_conv2d_batched_matches_loop_per_image(spec):
    s = sub.get_substrate(spec)
    imgs = RNG.integers(0, 128, (3, 12, 14)).astype(np.int32)
    kernel = jnp.asarray(conv.LAPLACIAN)
    got = np.asarray(conv.conv2d_batched(imgs, kernel, s))
    for i in range(imgs.shape[0]):
        ref = np.asarray(conv.conv2d_int(jnp.asarray(imgs[i]), kernel, s.scalar))
        if s.meta.scalar_faithful:
            np.testing.assert_array_equal(got[i], ref, err_msg=spec)
        else:
            # approx_stat rounds the separable correction once per output
            # element; the loop rounds per tap — difference < 1 per tap
            taps = int(np.prod(conv.LAPLACIAN.shape))
            np.testing.assert_allclose(got[i], ref, atol=taps, err_msg=spec)


def test_conv2d_batched_nhwc_channels():
    imgs = RNG.integers(0, 128, (2, 9, 9, 3)).astype(np.int32)
    s = sub.get_substrate("approx_bitexact")
    got = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s))
    assert got.shape == imgs.shape
    for b in range(2):
        for c in range(3):
            ref = np.asarray(conv.conv2d_int(
                jnp.asarray(imgs[b, :, :, c]), jnp.asarray(conv.LAPLACIAN),
                s.scalar))
            np.testing.assert_array_equal(got[b, :, :, c], ref)


# ---------------------------------------------------------------------------
# batched edge detection (acceptance: ≥8 images identical to single-image)
# ---------------------------------------------------------------------------


def test_edge_detect_batched_identical_to_single_image():
    imgs = image_batch(8, 32, 32)
    batched = np.asarray(
        conv.edge_detect_batched(imgs, "approx_bitexact:proposed"))
    assert batched.shape == imgs.shape and batched.dtype == np.uint8
    for i in range(8):
        single = np.asarray(conv.edge_detect(imgs[i], "proposed"))
        np.testing.assert_array_equal(batched[i], single)


def test_edge_detect_batched_pallas_substrate():
    imgs = image_batch(2, 16, 16)
    batched = np.asarray(conv.edge_detect_batched(imgs, "approx_pallas"))
    for i in range(2):
        single = np.asarray(conv.edge_detect(imgs[i], "proposed"))
        np.testing.assert_array_equal(batched[i], single)


def test_psnr_no_float64_warning():
    img = image_batch(2, 16, 16)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p = conv.psnr(img[0], img[1])
    assert np.isfinite(p)


# ---------------------------------------------------------------------------
# model dispatch (serving dispatch lives in tests/test_serving.py)
# ---------------------------------------------------------------------------


def _tiny_cfg(**overrides):
    return reg.get_config("minitron-8b", n_layers=1, d_model=32, d_ff=64,
                          vocab=64, n_heads=2, n_kv_heads=2, attn_chunk=16,
                          loss_chunk=16, remat=False, **overrides)


def test_bundle_resolves_substrate_once():
    bundle = reg.build_bundle(_tiny_cfg(dot_mode="approx_lut:design_du2022"))
    assert bundle.substrate is sub.get_substrate("approx_lut:design_du2022")
    assert bundle.substrate.meta.mult_name == "design_du2022"


def test_model_smoke_approx_pallas_end_to_end():
    """approx_pallas selectable via cfg.dot_mode (interpret mode on CPU)."""
    cfg = _tiny_cfg(dot_mode="approx_pallas")
    bundle = reg.build_bundle(cfg)
    assert bundle.substrate.meta.name == "approx_pallas"
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    logits = bundle.prefill(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_edge_detect_config_uses_parameterized_spec():
    cfg = reg.get_config("edge-detect")
    name, mult_name, width = sub.parse_spec(cfg.dot_mode)
    assert name == "approx_bitexact" and mult_name == "proposed" and width == 8
    assert reg.build_bundle(dataclasses.replace(cfg)).substrate.meta.bit_exact
