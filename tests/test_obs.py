"""Observability layer: registry, tracing, meters, export, integration.

Covers the PR-7 acceptance criteria: the disabled-telemetry no-op path
(bit-identical results, zero registry writes), span nesting and thread
isolation, Prometheus/Chrome-trace export schemas, execution-time metering
under jit, the online error probe against the offline LUT oracle, and one
end-to-end serving run yielding queue/compile/execute spans plus per-spec
contraction/energy counters in one combined registry dump.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut
from repro.nn import substrate as sub
from repro.nn.conv import edge_detect_batched
from repro.obs import (ContractionMeter, JsonlSink, MetricsRegistry, Tracer,
                       current_meter, current_tracer, pdp_per_mac_fj,
                       telemetry_scope, trace_span, tracing_scope,
                       write_chrome_trace, write_metrics)
from repro.serving.edge_service import EdgeDetectService
from repro.serving.metrics import ServingMetrics


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_labels_and_value(self):
        r = MetricsRegistry()
        c = r.counter("ops_total", "ops", ("kind",))
        c.labels(kind="a").inc()
        c.labels(kind="a").inc(3)
        c.labels(kind="b").inc(2)
        assert dict((l["kind"], v) for l, v in c.samples()) == \
            {"a": 4, "b": 2}

    def test_counter_rejects_negative(self):
        r = MetricsRegistry()
        c = r.counter("n_total")
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_setmax(self):
        r = MetricsRegistry()
        g = r.gauge("depth")
        g.set(5)
        g.inc(-2)
        assert g.value() == 3
        g.set_max(10)
        g.set_max(4)  # ratchet: no decrease
        assert g.value() == 10

    def test_histogram_cumulative_buckets(self):
        r = MetricsRegistry()
        h = r.histogram("lat", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        (_, snap), = h.samples()
        assert snap["count"] == 4
        assert snap["sum"] == pytest.approx(5.555)
        assert snap["buckets"] == {0.01: 1, 0.1: 2, 1.0: 3}

    def test_get_or_create_same_family(self):
        r = MetricsRegistry()
        assert r.counter("x_total", "h") is r.counter("x_total")

    def test_type_mismatch_rejected(self):
        r = MetricsRegistry()
        r.counter("m")
        with pytest.raises(ValueError):
            r.gauge("m")
        r2 = MetricsRegistry()
        r2.counter("m", labelnames=("a",))
        with pytest.raises(ValueError):
            r2.counter("m", labelnames=("b",))

    def test_label_set_must_match_declaration(self):
        r = MetricsRegistry()
        c = r.counter("x_total", labelnames=("spec",))
        with pytest.raises(ValueError):
            c.labels(wrong="v")
        with pytest.raises(ValueError):
            c.inc()  # labeled family needs .labels(...)

    def test_prometheus_text_schema(self):
        r = MetricsRegistry()
        r.counter("ops_total", "operations", ("spec",)) \
            .labels(spec='a"b\\c').inc(2)
        r.histogram("lat_seconds", "latency", buckets=(0.5, 1.0)).observe(0.7)
        text = r.to_prometheus()
        assert "# HELP ops_total operations" in text
        assert "# TYPE ops_total counter" in text
        # label escaping: backslash and quote
        assert 'ops_total{spec="a\\"b\\\\c"} 2' in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.5"} 0' in text
        assert 'lat_seconds_bucket{le="1.0"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 1' in text
        assert "lat_seconds_sum 0.7" in text
        assert "lat_seconds_count 1" in text

    def test_json_roundtrip(self):
        r = MetricsRegistry()
        r.counter("a_total", "help a", ("x",)).labels(x="1").inc()
        doc = json.loads(json.dumps(r.to_json()))
        assert doc["a_total"]["type"] == "counter"
        assert doc["a_total"]["samples"] == \
            [{"labels": {"x": "1"}, "value": 1}]

    def test_reset(self):
        r = MetricsRegistry()
        c = r.counter("a_total")
        c.inc(5)
        r.reset()
        assert c.value() == 0

    def test_thread_safety(self):
        r = MetricsRegistry()
        c = r.counter("n_total", labelnames=("t",))
        def work():
            for _ in range(1000):
                c.labels(t="x").inc()
        threads = [threading.Thread(target=work) for _ in range(8)]
        [t.start() for t in threads]
        [t.join() for t in threads]
        (_, v), = c.samples()
        assert v == 8000


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


class TestTracer:
    def test_nesting_depth_and_parent(self):
        t = Tracer()
        with t.span("outer"):
            with t.span("inner"):
                pass
        inner, outer = t.events()  # inner closes first
        assert inner["name"] == "inner" and outer["name"] == "outer"
        assert inner["args"]["depth"] == 1
        assert inner["args"]["parent"] == "outer"
        assert outer["args"]["depth"] == 0
        assert inner["ts"] >= outer["ts"]
        assert inner["dur"] <= outer["dur"]

    def test_thread_isolation(self):
        t = Tracer()
        done = threading.Event()
        def worker():
            with t.span("worker_span"):
                pass
            done.set()
        with t.span("main_span"):
            th = threading.Thread(target=worker)
            th.start()
            th.join()
        assert done.is_set()
        by_name = {e["name"]: e for e in t.events()}
        # the worker's span does NOT nest under the main thread's stack
        assert by_name["worker_span"]["args"]["depth"] == 0
        assert "parent" not in by_name["worker_span"]["args"]
        assert by_name["worker_span"]["tid"] != by_name["main_span"]["tid"]

    def test_chrome_trace_schema(self):
        t = Tracer()
        with t.span("s", "cat", foo="bar"):
            pass
        t.event("retro", t._clock() - 0.01, 0.01)
        t.instant("marker")
        doc = json.loads(json.dumps(t.chrome_trace()))
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        assert len(evs) == 3
        for e in evs:
            assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(e)
        xs = [e for e in evs if e["ph"] == "X"]
        assert len(xs) == 2 and all(e["dur"] >= 0 for e in xs)
        assert xs[0]["args"]["foo"] == "bar"

    def test_jsonl_sink(self, tmp_path):
        p = tmp_path / "spans.jsonl"
        t = Tracer()
        with JsonlSink(p) as sink:
            t.add_sink(sink)
            with t.span("a"):
                pass
            t.instant("b")
        lines = [json.loads(l) for l in p.read_text().splitlines()]
        assert [l["name"] for l in lines] == ["a", "b"]

    def test_ambient_scope(self):
        assert current_tracer() is None
        with trace_span("nothing"):  # no-op without a tracer
            pass
        t = Tracer()
        with tracing_scope(t):
            assert current_tracer() is t
            with trace_span("ambient"):
                pass
            with tracing_scope(None):  # nested None disables
                assert current_tracer() is None
        assert current_tracer() is None
        assert [e["name"] for e in t.events()] == ["ambient"]


# ---------------------------------------------------------------------------
# meters
# ---------------------------------------------------------------------------


SPEC = "approx_lut:proposed"


def _flush_callbacks():
    """Wait until every pending jax.debug.callback has run."""
    jax.effects_barrier()


class TestMeterPricing:
    def test_alias_resolves_to_same_price(self):
        assert pdp_per_mac_fj("csp_axc1") == \
            pdp_per_mac_fj("design_esposito2018")
        assert pdp_per_mac_fj("proposed") == pdp_per_mac_fj("proposed@8")

    def test_proposed_cheaper_than_exact(self):
        # the paper's headline: the proposed design undercuts exact PDP
        assert 0 < pdp_per_mac_fj("proposed") < pdp_per_mac_fj("exact")

    def test_width_scales_price(self):
        assert pdp_per_mac_fj("proposed@4") < pdp_per_mac_fj("proposed@8")


class TestMeterRecording:
    def test_disabled_path_no_writes_and_bit_identical(self):
        rng = np.random.default_rng(0)
        a = rng.integers(-128, 128, (8, 16), dtype=np.int32)
        b = rng.integers(-128, 128, (16, 4), dtype=np.int32)
        s = sub.get_substrate(SPEC)
        meter = ContractionMeter(error_probe=True)
        bare = np.asarray(s.dot_general(a, b))
        with telemetry_scope(meter):
            metered = np.asarray(s.dot_general(a, b))
        _flush_callbacks()
        after = np.asarray(s.dot_general(a, b))  # scope exited
        _flush_callbacks()
        assert np.array_equal(bare, metered)
        assert np.array_equal(bare, after)
        summ = meter.summary()
        assert summ[SPEC]["contractions"] == 1  # only the in-scope call
        assert summ[SPEC]["macs"] == 8 * 16 * 4

    def test_no_scope_means_empty_registry(self):
        rng = np.random.default_rng(1)
        a = rng.integers(-128, 128, (4, 8), dtype=np.int32)
        b = rng.integers(-128, 128, (8, 4), dtype=np.int32)
        meter = ContractionMeter(error_probe=True)
        assert current_meter() is None
        sub.get_substrate(SPEC).dot_general(a, b)
        _flush_callbacks()
        for fam in meter.registry.to_json().values():
            assert fam["samples"] == []

    def test_jit_counts_every_execution(self):
        rng = np.random.default_rng(2)
        a = rng.integers(-128, 128, (4, 8), dtype=np.int32)
        b = rng.integers(-128, 128, (8, 4), dtype=np.int32)
        s = sub.get_substrate(SPEC)
        f = jax.jit(lambda x, y: s.dot_general(x, y))
        meter = ContractionMeter()
        with telemetry_scope(meter):
            for _ in range(3):
                jax.block_until_ready(f(a, b))
            _flush_callbacks()
        assert meter.summary()[SPEC]["contractions"] == 3
        # compiled with a scope, executed without one: records nothing
        jax.block_until_ready(f(a, b))
        _flush_callbacks()
        assert meter.summary()[SPEC]["contractions"] == 3

    def test_energy_prices_through_unit_gate_model(self):
        rng = np.random.default_rng(3)
        a = rng.integers(-128, 128, (4, 8), dtype=np.int32)
        b = rng.integers(-128, 128, (8, 4), dtype=np.int32)
        meter = ContractionMeter()
        with telemetry_scope(meter):
            sub.get_substrate(SPEC).dot_general(a, b)
            _flush_callbacks()
        row = meter.summary()[SPEC]
        assert row["energy_pdp_fj"] == \
            pytest.approx(row["macs"] * pdp_per_mac_fj("proposed"))

    def test_exact_float_path_metered_without_probe(self):
        meter = ContractionMeter(error_probe=True)
        s = sub.get_substrate("exact")
        x = np.linspace(-1, 1, 32, dtype=np.float32).reshape(4, 8)
        w = np.linspace(-1, 1, 16, dtype=np.float32).reshape(8, 2)
        with telemetry_scope(meter):
            s.dot(x, w)
            _flush_callbacks()
        assert meter.summary()["exact:exact"]["contractions"] == 1
        assert meter.probe_moments() == {}  # exact backends are never probed

    def test_edge_detect_batched_bit_identical_under_scope(self):
        rng = np.random.default_rng(4)
        imgs = rng.integers(0, 256, (2, 16, 16), dtype=np.uint8)
        bare = np.asarray(edge_detect_batched(imgs, SPEC))
        meter = ContractionMeter(error_probe=True)
        with telemetry_scope(meter):
            metered = np.asarray(edge_detect_batched(imgs, SPEC))
        _flush_callbacks()
        assert np.array_equal(bare, metered)
        assert meter.summary()[SPEC]["macs"] == 2 * 16 * 16 * 9

    def test_fused_conv_path_metered(self):
        rng = np.random.default_rng(5)
        imgs = rng.integers(0, 256, (2, 16, 16), dtype=np.uint8)
        spec = "approx_pallas:proposed"
        bare = np.asarray(edge_detect_batched(imgs, spec))
        meter = ContractionMeter(error_probe=True)
        with telemetry_scope(meter):
            metered = np.asarray(edge_detect_batched(imgs, spec))
        _flush_callbacks()
        assert np.array_equal(bare, metered)
        row = meter.summary()[spec]
        # same MAC accounting as the im2col path: B*H*W pixels x 9 taps
        assert row["macs"] == 2 * 16 * 16 * 9
        assert meter.probe_moments(spec)["n"] > 0


class TestErrorProbe:
    def test_moments_match_offline_lut_oracle(self):
        """Online probe moments vs core.lut on a bitexact wiring.

        Operands drawn uniform over the full signed range, fresh every
        iteration. The probe measures products over a rows x cols cross of
        operand draws, so the mean's effective sample size is the operand
        count (the products are correlated through shared operands), not
        the product count — the tolerance uses that.
        """
        key = "proposed"
        s = sub.get_substrate(f"approx_lut:{key}")
        rows = cols = kk = 64
        iters = 4
        meter = ContractionMeter(error_probe=True, probe_rows=rows,
                                 probe_cols=cols, probe_k=kk, seed=7)
        rng = np.random.default_rng(11)
        with telemetry_scope(meter):
            for _ in range(iters):
                a = rng.integers(-128, 128, (rows, kk), dtype=np.int32)
                b = rng.integers(-128, 128, (kk, cols), dtype=np.int32)
                s.dot_general(a, b)
            _flush_callbacks()
        mom = meter.probe_moments(f"approx_lut:{key}")
        assert mom["n"] == rows * kk * cols * iters
        oracle = lut.error_moments(key)
        med_oracle = float(np.abs(lut.error_lut(key)).mean())
        n_eff = rows * kk * iters  # independent lhs operand draws
        tol = 6 * oracle["std"] / np.sqrt(n_eff)
        assert mom["mean"] == pytest.approx(oracle["mean"], abs=tol)
        assert mom["med"] == pytest.approx(med_oracle, rel=0.1)
        assert 0 < mom["max_ed"] <= oracle["max_abs"]

    def test_max_ed_bounded_by_oracle_for_other_wiring(self):
        key = "design_du2022"
        s = sub.get_substrate(f"approx_lut:{key}")
        meter = ContractionMeter(error_probe=True, probe_rows=32,
                                 probe_cols=32, seed=3)
        rng = np.random.default_rng(13)
        a = rng.integers(-128, 128, (32, 32), dtype=np.int32)
        b = rng.integers(-128, 128, (32, 32), dtype=np.int32)
        with telemetry_scope(meter):
            s.dot_general(a, b)
            _flush_callbacks()
        mom = meter.probe_moments(f"approx_lut:{key}")
        assert mom["max_ed"] <= lut.error_moments(key)["max_abs"]


# ---------------------------------------------------------------------------
# ServingMetrics on the registry
# ---------------------------------------------------------------------------


class TestServingMetricsRegistry:
    def test_snapshot_shape_unchanged(self):
        m = ServingMetrics()
        m.record_enqueue(3)
        m.record_batch(2, "size", 4)
        m.record_done(0.01, depth=1)
        m.record_compile()
        s = m.snapshot()
        assert s["requests_enqueued"] == 1
        assert s["batches_by_reason"] == {"size": 1}
        assert s["occupancy_hist"] == {2: 1}
        assert s["compiled_calls"] == 1
        assert isinstance(s["requests_served"], int)

    def test_prometheus_export_of_serving_counters(self):
        m = ServingMetrics()
        m.record_enqueue(1)
        m.record_done(0.002)
        text = m.registry.to_prometheus()
        assert "serving_requests_enqueued_total 1" in text
        assert "serving_requests_served_total 1" in text
        assert "serving_request_latency_seconds_count 1" in text

    def test_reset_only_touches_serving_families(self):
        reg = MetricsRegistry()
        other = reg.counter("substrate_contractions_total", "", ("spec",))
        other.labels(spec="x").inc(5)
        m = ServingMetrics(registry=reg)
        m.record_enqueue(1)
        m.reset()
        assert m.requests_enqueued == 0
        (_, v), = other.samples()
        assert v == 5

    def test_throughput_reads_under_lock(self):
        # functional regression guard for the unlocked-read fix: concurrent
        # reset()/throughput() must not raise or return garbage
        m = ServingMetrics()
        stop = threading.Event()
        errors = []
        def reader():
            try:
                while not stop.is_set():
                    assert m.throughput() >= 0.0
            except BaseException as e:  # noqa: BLE001
                errors.append(e)
        th = threading.Thread(target=reader)
        th.start()
        for _ in range(200):
            m.record_done(0.001)
            m.reset()
        stop.set()
        th.join()
        assert not errors


# ---------------------------------------------------------------------------
# export helpers
# ---------------------------------------------------------------------------


class TestExport:
    def test_write_metrics_suffix_dispatch(self, tmp_path):
        r = MetricsRegistry()
        r.counter("a_total").inc()
        prom = write_metrics(r, tmp_path / "m.prom")
        assert "# TYPE a_total counter" in prom.read_text()
        js = write_metrics(r, tmp_path / "m.json", extra={"note": "hi"})
        doc = json.loads(js.read_text())
        assert doc["note"] == "hi"
        assert doc["metrics"]["a_total"]["samples"][0]["value"] == 1

    def test_write_chrome_trace(self, tmp_path):
        t = Tracer()
        with t.span("s"):
            pass
        p = write_chrome_trace(t, tmp_path / "trace.json")
        doc = json.loads(p.read_text())
        assert doc["traceEvents"][0]["name"] == "s"


# ---------------------------------------------------------------------------
# end-to-end: one serving run, one combined dump
# ---------------------------------------------------------------------------


class TestServingIntegration:
    def test_serving_run_yields_spans_and_combined_registry(self):
        reg = MetricsRegistry()
        tracer = Tracer()
        meter = ContractionMeter(reg, error_probe=True)
        rng = np.random.default_rng(0)
        imgs = [rng.integers(0, 256, (16, 16), dtype=np.uint8)
                for _ in range(4)]
        with tracing_scope(tracer), telemetry_scope(meter):
            svc = EdgeDetectService(
                SPEC, max_batch_size=2, max_wait_s=0.5,
                metrics=ServingMetrics(registry=reg))
            outs = svc.detect(imgs)
            svc.close()
            _flush_callbacks()

        # (a) Chrome trace with queue/compile/execute spans
        doc = json.loads(json.dumps(tracer.chrome_trace()))
        names = {e["name"] for e in doc["traceEvents"]}
        # 2 same-shape batches: first compiles, second hits the jit cache
        assert {"batch.queue_wait", "batch.process", "edge.pad",
                "edge.compile", "edge.execute", "edge.crop"} <= names
        for e in doc["traceEvents"]:
            assert e["ph"] in ("X", "i") and e["ts"] >= 0

        # (b) one Prometheus dump with serving + substrate series
        text = reg.to_prometheus()
        assert "serving_requests_served_total 4" in text
        assert f'substrate_contractions_total{{spec="{SPEC}"' in text
        assert f'substrate_energy_pdp_fj_total{{spec="{SPEC}"' in text

        # (c) probe moments within the offline oracle's envelope
        mom = meter.probe_moments(SPEC)
        assert mom["n"] > 0
        assert mom["max_ed"] <= lut.error_moments("proposed")["max_abs"]

        # served maps bit-identical to the direct pipeline
        direct = np.asarray(edge_detect_batched(np.stack(imgs), SPEC))
        for o, d in zip(outs, direct):
            assert np.array_equal(o, d)
