"""Compressor truth tables and statistics vs paper Table 2 (exact match)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as comp


def test_exact3_is_sum_plus_one():
    assert list(comp.EXACT3.values) == [1, 2, 2, 3, 2, 3, 3, 4]
    assert (comp.EXACT3.errors == 0).all()


def test_exact4_is_sum_plus_one():
    assert (comp.EXACT4.errors == 0).all()
    assert comp.EXACT4.values[0b1111] == 5


@pytest.mark.parametrize("name,stats", sorted(comp.PAPER_TABLE2_STATS.items()))
def test_table2_pe_emean(name, stats):
    """P_E and E_mean match the paper's Table 2 bottom rows exactly."""
    c = comp.ALL_3INPUT[name]
    pe, emean = stats
    assert c.error_probability() == pytest.approx(pe, abs=1e-12)
    assert c.mean_error() == pytest.approx(emean, abs=1e-12)


def test_proposed3_gates_match_table():
    """Gate-level boolean form reproduces the truth table bit-for-bit."""
    for idx in range(8):
        a, b, c = (idx >> 2) & 1, (idx >> 1) & 1, idx & 1
        carry, s = comp.proposed3_gates(jnp.array(a), jnp.array(b), jnp.array(c))
        assert 2 * int(carry) + int(s) == comp.PROPOSED3.values[idx]


def test_proposed4_gates_match_table():
    for idx in range(16):
        a, b, c, d = (idx >> 3) & 1, (idx >> 2) & 1, (idx >> 1) & 1, idx & 1
        carry, s = comp.proposed4_gates(*map(jnp.array, (a, b, c, d)))
        assert 2 * int(carry) + int(s) == comp.PROPOSED4.values[idx]


def test_proposed4_reconstruction_stats():
    """DESIGN.md §3 reconstruction: P_E = 58/256, E_mean = +7/256."""
    c = comp.PROPOSED4
    assert c.error_probability() == pytest.approx(58 / 256, abs=1e-12)
    assert c.mean_error() == pytest.approx(7 / 256, abs=1e-12)
    # error cases sit on low-probability combos (each ≤ 9/256)
    probs = c.input_probs()
    assert probs[c.errors != 0].max() <= 9 / 256 + 1e-12


def test_proposed4_table3_fragments():
    """Legible fragments of paper Table 3: row 1111 → approx 3 (ED −2);
    row 1000 (highest-probability combo) is exact."""
    c = comp.PROPOSED4
    assert c.values[0b1111] == 3 and c.errors[0b1111] == -2
    assert c.errors[0b1000] == 0
    assert c.values[0b0000] == 1  # 0+1 exact


def test_input_probability_distribution():
    """A is NAND-generated (P=3/4), rest AND-generated (P=1/4); probs sum to 1."""
    for c in comp.ALL.values():
        p = c.input_probs()
        assert p.sum() == pytest.approx(1.0)
    p3 = comp.PROPOSED3.input_probs()
    assert p3[0b100] == pytest.approx(27 / 64)  # A=1,B=0,C=0
    p4 = comp.PROPOSED4.input_probs()
    assert p4[0b1000] == pytest.approx(81 / 256)
    assert p4[0b0000] == pytest.approx(27 / 256)


def test_pack_bits():
    idx = comp.pack_bits([jnp.array(1), jnp.array(0), jnp.array(1)])
    assert int(idx) == 0b101
    idx4 = comp.pack_bits([jnp.array(1), jnp.array(1), jnp.array(0), jnp.array(1)])
    assert int(idx4) == 0b1101


def test_carry_sum_bits_consistent():
    for c in comp.ALL.values():
        if c.name.startswith("exact"):
            continue
        idx = jnp.arange(2 ** c.n_inputs)
        v = 2 * c.carry_bit(idx) + c.sum_bit(idx)
        np.testing.assert_array_equal(np.asarray(v), c.values)


def test_approximate_values_at_most_3():
    """Approximate designs emit only {carry, sum} — values ≤ 3."""
    for c in comp.ALL.values():
        if c.name.startswith("exact"):
            continue
        assert c.values.max() <= 3, c.name
