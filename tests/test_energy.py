"""Unit-gate hardware model: calibration, orderings, headline savings."""
import pytest

from repro.core import energy


def test_calibration_on_exact_row():
    e = energy.estimate("exact")
    assert e["area"] == pytest.approx(2204.75, rel=1e-6)
    assert e["power"] == pytest.approx(178.10, rel=1e-6)
    assert e["delay"] == pytest.approx(3.28, rel=1e-6)


def test_proposed_is_best_on_power_and_pdp():
    t = energy.table5()
    prop = t["proposed"]
    for name, row in t.items():
        if name == "proposed":
            continue
        assert prop["power"] < row["power"], name
        assert prop["pdp"] < row["pdp"], name


def test_headline_savings_vs_du2022():
    """Paper: −14.39 % power, −29.21 % PDP vs [2]. Model bands: 8–30 / 15–45."""
    s = energy.savings_vs("proposed", "design_du2022")
    assert 8.0 < s["power"] < 30.0
    assert 15.0 < s["pdp"] < 45.0
    assert s["delay"] > 0  # proposed is also faster (paper: 2.10 vs 2.54 ns)


def test_truncation_saves_over_half_the_power():
    s = energy.savings_vs("proposed", "exact")
    assert s["power"] > 40.0
    assert s["area"] > 40.0


def test_orderings_match_paper_where_structural():
    """Truncating designs ([2], proposed) are smaller than tree-wide ones."""
    t = energy.table5()
    for tree_wide in ("design_esposito2018", "design_strollo2020", "design_akbari2017"):
        assert t["proposed"]["area"] < t[tree_wide]["area"]
        assert t["design_du2022"]["area"] < t[tree_wide]["area"]


def test_reduce_columns_terminates_and_counts():
    n_fa, n_ha, stages = energy.reduce_columns([8, 8, 8, 8])
    assert n_fa > 0 and stages >= 3
    n_fa2, _, stages2 = energy.reduce_columns([2, 2])
    assert n_fa2 == 0 and stages2 == 0


def test_all_designs_estimable():
    for d in energy.DESIGNS:
        e = energy.estimate(d)
        assert all(v > 0 for v in e.values()), d
