"""Width-parametric multiplier contract: N∈{4, 8, 16}.

Parity oracle is the width-N Baugh-Wooley PPM construction: the exact BW
model must reproduce a·b, and every CSP wiring's closed form must equal the
independent structural PPM/compressor model (``StructuralMultiplier``) —
exhaustively at N=4 and N=8, sampled at N=16 (the 2^32 grid is not
enumerable). Plus: LUT==bitexact per width, substrate-spec ``@N``
round-trips, quantization clamps, and width-aware error moments.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_lib
from repro.core import metrics, multiplier as m
from repro.nn import conv, quant
from repro.nn import substrate as sub

RNG = np.random.default_rng(23)

WIRING_NAMES = sorted(m.WIRINGS)


def _grid(n):
    a, b = metrics.operand_grid(n)
    return np.asarray(a), np.asarray(b)


def _sample(n, k=20000, seed=5):
    a, b = metrics.sample_operands(n, k, seed)
    return np.asarray(a), np.asarray(b)


# ---------------------------------------------------------------------------
# Baugh-Wooley reference parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 8], ids=["n4", "n8"])
def test_exact_baugh_wooley_exhaustive(n):
    a, b = _grid(n)
    got = np.asarray(jax.jit(lambda x, y: m.exact_baugh_wooley(x, y, n))(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, a.astype(np.int64) * b.astype(np.int64))


def test_exact_baugh_wooley_sampled_n16():
    a, b = _sample(16)
    got = np.asarray(m.exact_baugh_wooley(jnp.asarray(a), jnp.asarray(b), 16))
    np.testing.assert_array_equal(got, a.astype(np.int64) * b.astype(np.int64))


@pytest.mark.parametrize("name", WIRING_NAMES)
@pytest.mark.parametrize("n", [4, 8], ids=["n4", "n8"])
def test_closed_form_equals_structural_exhaustive(name, n):
    """Every wiring, exhaustive over the width-N operand grid."""
    a, b = _grid(n)
    w = m.WIRINGS[name]
    closed = np.asarray(jax.jit(
        lambda x, y: m.approx_multiply_with(x, y, w, n))(
            jnp.asarray(a), jnp.asarray(b)))
    structural = np.asarray(jax.jit(m.StructuralMultiplier(n, w))(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(closed, structural)


@pytest.mark.parametrize("name", WIRING_NAMES)
def test_closed_form_equals_structural_sampled_n16(name):
    a, b = _sample(16)
    w = m.WIRINGS[name]
    closed = np.asarray(m.approx_multiply_with(
        jnp.asarray(a), jnp.asarray(b), w, 16))
    structural = np.asarray(m.StructuralMultiplier(16, w)(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(closed, structural)


def test_closed_form_equals_structural_hypothesis_n16():
    """Property-based spot check at N=16 (runs only if hypothesis exists)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(-(1 << 15), (1 << 15) - 1),
               st.integers(-(1 << 15), (1 << 15) - 1))
    @hyp.settings(max_examples=200, deadline=None)
    def check(a, b):
        closed = int(m.approx_multiply_with(
            jnp.asarray(a), jnp.asarray(b), m.PROPOSED_WIRING, 16))
        structural = int(m.StructuralMultiplier(16)(
            jnp.asarray(a), jnp.asarray(b)))
        assert closed == structural

    check()


def test_operand_wraparound_semantics():
    """Out-of-range ints wrap to their low-n-bits value in every model."""
    a = jnp.asarray([8, 200, -9])  # at n=4: 8→-8, 200→-8+... wraps
    b = jnp.asarray([3, 3, 3])
    aw = m.wrap_operand(a, 4)
    np.testing.assert_array_equal(np.asarray(aw), [-8, -8, 7])
    direct = np.asarray(m.approx_multiply_with(a, b, m.PROPOSED_WIRING, 4))
    wrapped = np.asarray(m.approx_multiply_with(aw, b, m.PROPOSED_WIRING, 4))
    np.testing.assert_array_equal(direct, wrapped)


def test_compensation_constant_tracks_expected_truncation():
    """comp_n = (n-2)·2^(n-3) = floor(E[T_T]) at every width (frac = 1/4)."""
    for n in range(4, 17):
        assert m.compensation_constant(n) == int(m.expected_truncation(n))
        assert abs(m.expected_truncation(n) - m.compensation_constant(n)) == 0.25
    assert m.compensation_constant(8) == 192  # the paper's 2^7 + 2^6
    assert m.compensation_constant(4) == 4


def test_width_bounds_rejected():
    with pytest.raises(ValueError, match="operand width"):
        m.make_multiplier("proposed", 2)
    with pytest.raises(ValueError, match="operand width"):
        m.make_multiplier("proposed", 17)
    with pytest.raises(ValueError, match="bad width suffix"):
        m.split_width("proposed@banana")


def test_wiring_aliases_resolve():
    key, fn, n = m.resolve_multiplier("csp_axc1@4")
    assert key == "design_esposito2018@4" and n == 4
    a, b = _grid(4)
    np.testing.assert_array_equal(
        np.asarray(fn(jnp.asarray(a), jnp.asarray(b))),
        np.asarray(m.ALL_MULTIPLIERS["design_esposito2018@4"](
            jnp.asarray(a), jnp.asarray(b))))


def test_all_multipliers_has_width_variants():
    for name in m.WIRINGS:
        assert f"{name}@4" in m.ALL_MULTIPLIERS
        assert f"{name}@16" in m.ALL_MULTIPLIERS
    assert set(m.default_width_names()) == {"exact", *m.WIRINGS}


# ---------------------------------------------------------------------------
# LUT == bitexact per width
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 8], ids=["n4", "n8"])
def test_lut_matches_closed_form_per_width(n):
    table = lut_lib.build_lut(f"proposed@{n}")
    assert table.shape == (1 << n, 1 << n)
    a, b = _grid(n)
    direct = np.asarray(m.make_multiplier("proposed", n)(
        jnp.asarray(a), jnp.asarray(b)))
    via_lut = np.asarray(lut_lib.lut_multiply(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(table)))
    np.testing.assert_array_equal(direct, via_lut)


def test_lut_key_canonicalization_shares_tables():
    assert lut_lib.build_lut("proposed") is lut_lib.build_lut("proposed@8")
    assert lut_lib.build_lut("csp_axc5@4") is lut_lib.build_lut("design_du2022@4")


def test_lut_rejects_wide_widths():
    with pytest.raises(ValueError, match="exhaustive LUTs"):
        lut_lib.build_lut("proposed@16")


def test_error_lut_and_moments_width_aware():
    e4 = lut_lib.error_lut("proposed@4")
    assert e4.shape == (16, 16)
    mom = lut_lib.error_moments("proposed@4")
    assert abs(mom["mean"] - e4.astype(np.float64).mean()) < 1e-9
    # 4-bit errors are small absolute numbers (truncation ≤ 2^2-ish scale)
    assert mom["max_abs"] < 64


def test_substrate_lut_equals_bitexact_width4_on_arbitrary_ints():
    """Wrap semantics: parity must hold even for out-of-4-bit-range int8."""
    a8 = RNG.integers(-128, 128, (6, 13)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (13, 4)).astype(np.int8)
    bx = np.asarray(sub.get_substrate("approx_bitexact:proposed@4").dot_int8(a8, b8))
    lt = np.asarray(sub.get_substrate("approx_lut:proposed@4").dot_int8(a8, b8))
    np.testing.assert_array_equal(bx, lt)


def test_stat_substrate_wraps_operands_at_narrow_width():
    """approx_stat's contraction must wrap out-of-range operands like its
    own scalar model (a K=1 contraction and the scalar agree exactly)."""
    s = sub.get_substrate("approx_stat:proposed@4")
    for a, b in [(8, 3), (-9, 5), (200, -1), (7, 7)]:
        got = int(s.dot_int8(np.array([[a]], np.int16),
                             np.array([[b]], np.int16))[0, 0])
        want = int(s.scalar(jnp.asarray(a), jnp.asarray(b)))
        assert got == want, (a, b)


def test_substrate_dot_width16_matches_scalar_sum_mod32():
    s = sub.get_substrate("approx_bitexact:proposed@16")
    a = RNG.integers(-32768, 32768, (4, 11)).astype(np.int64)
    b = RNG.integers(-32768, 32768, (11, 3)).astype(np.int64)
    oracle = np.asarray(
        s.scalar(jnp.asarray(a[:, :, None], jnp.int32),
                 jnp.asarray(b[None, :, :], jnp.int32)),
        dtype=np.int64).sum(axis=1)
    oracle = ((oracle + 2**31) % 2**32 - 2**31).astype(np.int32)  # int32 ring
    got = np.asarray(s.dot_int8(a.astype(np.int16), b.astype(np.int16)))
    np.testing.assert_array_equal(got, oracle)


# ---------------------------------------------------------------------------
# substrate spec grammar round-trip
# ---------------------------------------------------------------------------


def test_spec_roundtrip_at_width():
    for spec, backend, name, width in [
        ("approx_lut:csp_axc1@4", "approx_lut", "csp_axc1", 4),
        ("approx_bitexact:proposed@16", "approx_bitexact", "proposed", 16),
        ("approx_stat:design_du2022@4", "approx_stat", "design_du2022", 4),
    ]:
        parts = sub.parse_spec(spec)
        assert parts == (backend, name, width)
        s = sub.get_substrate(spec)
        assert (s.meta.name, s.meta.mult_name, s.meta.width) == (backend, name, width)
        assert s.meta.spec == spec
        assert sub.get_substrate(s.meta.spec) is s  # round-trip hits the cache


def test_width_unsupported_backends_reject():
    with pytest.raises(ValueError, match="approx_lut needs an enumerable"):
        sub.get_substrate("approx_lut:proposed@16")
    with pytest.raises(ValueError, match="separable error model"):
        sub.get_substrate("approx_stat:proposed@16")
    with pytest.raises(ValueError, match="enumerable product table"):
        sub.get_substrate("approx_pallas:proposed@16")
    # the LUT kernel serves narrow widths now (PR 4) — @4 must *succeed*
    assert sub.get_substrate("approx_pallas:proposed@4").meta.width == 4


def test_default_spec_width_is_8():
    assert sub.parse_spec("approx_lut") == ("approx_lut", "proposed", 8)
    assert sub.get_substrate("approx_lut").meta.width == 8
    assert sub.get_substrate("approx_lut").meta.label == "approx_lut"
    assert sub.get_substrate("approx_lut:proposed@4").meta.label \
        == "approx_lut:proposed@4"


# ---------------------------------------------------------------------------
# quantization widths
# ---------------------------------------------------------------------------


def test_quantize_bits_ranges_and_dtypes():
    x = jnp.asarray(RNG.normal(size=(64,)).astype(np.float32)) * 100.0
    q4 = quant.quantize(x, bits=4)
    assert q4.values.dtype == jnp.int8
    assert int(jnp.abs(q4.values).max()) <= 7
    q16 = quant.quantize(x, bits=16)
    assert q16.values.dtype == jnp.int16
    assert int(jnp.abs(q16.values).max()) <= 32767
    # finer width → finer reconstruction
    err4 = float(jnp.abs(q4.dequantize() - x).max())
    err16 = float(jnp.abs(q16.dequantize() - x).max())
    assert err16 < err4


# ---------------------------------------------------------------------------
# conv / edge detection across widths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["approx_bitexact:proposed@4",
                                  "approx_bitexact:proposed@16"])
def test_conv2d_batched_matches_loop_at_width(spec):
    s = sub.get_substrate(spec)
    n = s.meta.width
    hi = 1 << (n - 1)
    imgs = RNG.integers(0, hi, (2, 10, 11)).astype(np.int32)
    kernel = jnp.asarray(conv.LAPLACIAN)
    got = np.asarray(conv.conv2d_batched(imgs, kernel, s))
    for i in range(imgs.shape[0]):
        ref = np.asarray(conv.conv2d_int(jnp.asarray(imgs[i]), kernel, s.scalar))
        np.testing.assert_array_equal(got[i], ref, err_msg=spec)


def test_edge_detect_batched_width4_matches_single_image():
    from repro.data import image_batch

    imgs = image_batch(3, 16, 16)
    batched = np.asarray(
        conv.edge_detect_batched(imgs, "approx_bitexact:proposed@4"))
    assert batched.shape == imgs.shape and batched.dtype == np.uint8
    for i in range(3):
        single = np.asarray(conv.edge_detect(imgs[i], "proposed@4"))
        np.testing.assert_array_equal(batched[i], single)


def test_edge_detect_width16_batched_matches_single_image():
    """Width-16 edge detection is deterministic and batched==single-image.

    (No closeness-to-exact assertion: the truncated/compensated framework
    assumes both operands span the full width, while edge-detection
    coefficients are ≤ 8 — at N=16 the 2^15 truncation cut dominates the
    ~2^18 products, so absolute edge-map quality is *worse* than at N=8
    even though NMED over uniform operands improves; see
    docs/compressors.md. The parity contract is what must hold.)"""
    from repro.data import image_batch

    imgs = image_batch(2, 16, 16)
    batched = np.asarray(
        conv.edge_detect_batched(imgs, "approx_bitexact:proposed@16"))
    assert batched.shape == imgs.shape and batched.dtype == np.uint8
    for i in range(2):
        single = np.asarray(conv.edge_detect(imgs[i], "proposed@16"))
        np.testing.assert_array_equal(batched[i], single)


# ---------------------------------------------------------------------------
# sampled error metrics + energy width scaling
# ---------------------------------------------------------------------------


def test_evaluate_sampled_zero_error_for_exact():
    rep = metrics.evaluate_sampled(m.exact_multiply, "exact", 16, 4096)
    assert rep.er == 0 and rep.med == 0


def test_evaluate_rejects_unenumerable_grid():
    with pytest.raises(ValueError, match="exhaustive grid"):
        metrics.operand_grid(16)


def test_relative_error_improves_with_width():
    """Truncation error is relatively smaller at larger N (paper Eq. 5:
    E[T_T]/max|product| shrinks), so NMED must fall from 4 → 8 → 16 bit."""
    nmed = {}
    for n in (4, 8):
        nmed[n] = metrics.evaluate(
            m.make_multiplier("proposed", n), n_bits=n).nmed
    nmed[16] = metrics.evaluate_sampled(
        m.make_multiplier("proposed", 16), n_bits=16, n_samples=1 << 15).nmed
    assert nmed[16] < nmed[8] < nmed[4]


def test_energy_scales_with_width():
    from repro.core import energy

    costs = [energy.estimate("proposed", n)["area"] for n in (4, 8, 16)]
    assert costs[0] < costs[1] < costs[2]
    # default width unchanged vs the calibrated Table-5 path
    assert energy.estimate("proposed", 8) == energy.estimate("proposed")
