"""The ``dot_general`` contraction surface: dimension numbers, QuantPolicy,
Partitioning.

* dimension-number handling (batch dims, transposed contractions, multi free
  dims) against ``jax.lax.dot_general`` on the exact backend, and against
  stacked 2-D calls on the approx backends;
* the float path (QuantPolicy) is bit-identical to the historical ``dot``
  wrapper, supports per-tensor/per-channel modes and pinned scales;
* the epsilon-guarded scale: all-zero activations produce exact zeros (the
  zero-image → zero-edge-map regression), never NaN;
* sharded-vs-unsharded bit-identity under 8 forced host devices, via the
  ``tests/test_distributed.py`` subprocess harness (per-K-shard f(0,0)
  correction, psum_scatter vs psum fallback, non-divisible M and K).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_distributed import run_py

from repro.nn import conv
from repro.nn import substrate as sub
from repro.nn.substrate import ContractionSpec, Partitioning, QuantPolicy

RNG = np.random.default_rng(7)

ALL_SPECS = ("exact", "int8", "approx_bitexact", "approx_lut",
             "approx_stat", "approx_pallas")


# ---------------------------------------------------------------------------
# dimension-number handling (integer domain) vs jax.lax.dot_general
# ---------------------------------------------------------------------------

# (lhs_shape, rhs_shape, dimension_numbers)
DIM_CASES = [
    # plain matmul
    ((5, 7), (7, 3), (((1,), (0,)), ((), ()))),
    # negative-axis default (the MATMUL_DIMS convention)
    ((5, 7), (7, 3), (((-1,), (0,)), ((), ()))),
    # transposed lhs contraction: x is (K, M)
    ((7, 5), (7, 3), (((0,), (0,)), ((), ()))),
    # transposed rhs: w is (N, K)
    ((5, 7), (3, 7), (((1,), (1,)), ((), ()))),
    # batch dims
    ((2, 5, 7), (2, 7, 3), (((2,), (1,)), ((0,), (0,)))),
    # batch dim not leading on the rhs
    ((2, 5, 7), (7, 2, 3), (((2,), (0,)), ((0,), (1,)))),
    # multiple lhs free dims (the im2col conv shape)
    ((2, 3, 4, 9), (9, 1), (((3,), (0,)), ((), ()))),
    # multiple contracting dims
    ((5, 2, 3), (2, 3, 4), (((1, 2), (0, 1)), ((), ()))),
    # rank-1 lhs (historical dot on a vector)
    ((7,), (7, 3), (((0,), (0,)), ((), ()))),
]


@pytest.mark.parametrize("case", DIM_CASES,
                         ids=[str(i) for i in range(len(DIM_CASES))])
def test_exact_dims_match_lax_dot_general(case):
    lhs_shape, rhs_shape, dims = case
    a = RNG.integers(-100, 100, lhs_shape).astype(np.int8)
    b = RNG.integers(-100, 100, rhs_shape).astype(np.int8)
    got = np.asarray(sub.get_substrate("exact").dot_general(
        jnp.asarray(a), jnp.asarray(b), ContractionSpec(dims)))
    norm = tuple(tuple(tuple(d % len(s) for d in axes)
                       for axes, s in zip(pair, (lhs_shape, rhs_shape)))
                 for pair in dims)
    ref = np.asarray(jax.lax.dot_general(
        jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), norm))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("spec", ["approx_bitexact", "approx_lut"])
def test_batch_dims_match_stacked_2d(spec):
    """Batched contraction == per-slice dot_int, and lut == bitexact."""
    s = sub.get_substrate(spec)
    a = RNG.integers(-128, 128, (3, 5, 19)).astype(np.int8)
    b = RNG.integers(-128, 128, (3, 19, 4)).astype(np.int8)
    dims = (((2,), (1,)), ((0,), (0,)))
    got = np.asarray(s.dot_general(jnp.asarray(a), jnp.asarray(b),
                                   ContractionSpec(dims)))
    ref = np.stack([np.asarray(s.dot_int(a[i], b[i])) for i in range(3)])
    np.testing.assert_array_equal(got, ref, err_msg=spec)


def test_conv2d_batched_still_matches_loop():
    """The im2col + dot_general rewrite keeps the tap-loop parity."""
    imgs = RNG.integers(0, 128, (2, 10, 11)).astype(np.int32)
    kernel = jnp.asarray(conv.LAPLACIAN)
    s = sub.get_substrate("approx_bitexact")
    got = np.asarray(conv.conv2d_batched(imgs, kernel, s))
    for i in range(imgs.shape[0]):
        ref = np.asarray(conv.conv2d_int(jnp.asarray(imgs[i]), kernel,
                                         s.scalar))
        np.testing.assert_array_equal(got[i], ref)


def test_dimension_number_validation():
    s = sub.get_substrate("exact")
    a = jnp.zeros((4, 5), jnp.int8)
    b = jnp.zeros((6, 3), jnp.int8)
    with pytest.raises(ValueError, match="contracting dimension mismatch"):
        s.dot_general(a, b, ContractionSpec((((1,), (0,)), ((), ()))))
    with pytest.raises(ValueError, match="out of range"):
        s.dot_general(a, a, ContractionSpec((((3,), (0,)), ((), ()))))
    with pytest.raises(ValueError, match="duplicate"):
        s.dot_general(a, a, ContractionSpec((((1, 1), (0, 0)), ((), ()))))
    with pytest.raises(ValueError, match="both contracting and batch"):
        s.dot_general(a, a, ContractionSpec((((0,), (0,)), ((0,), (1,)))))
    with pytest.raises(ValueError, match="must pair up"):
        s.dot_general(a, a, ContractionSpec((((1,), ()), ((), ()))))
    with pytest.raises(TypeError, match="integer-domain"):
        sub.get_substrate("int8").dot_general(
            jnp.zeros((4, 5), jnp.float32), jnp.zeros((5, 3), jnp.float32))


# ---------------------------------------------------------------------------
# QuantPolicy: float path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_float_path_bit_identical_to_dot_wrapper(spec):
    s = sub.get_substrate(spec)
    x = jnp.asarray(RNG.normal(size=(3, 5, 24)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(24, 6)).astype(np.float32))
    ref = np.asarray(s.dot(x, w))
    got = np.asarray(s.dot_general(
        x, w, ContractionSpec.matmul(quant=QuantPolicy())))
    np.testing.assert_array_equal(got, ref, err_msg=spec)


def test_quant_modes_and_bits():
    x = jnp.asarray(RNG.normal(size=(4, 16)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))
    ref = jnp.dot(x, w)
    s = sub.get_substrate("approx_bitexact")
    for policy in (QuantPolicy(), QuantPolicy(w_mode="per_tensor"),
                   QuantPolicy(x_mode="per_channel")):
        out = s.dot_general(x, w, ContractionSpec.matmul(quant=policy))
        assert out.shape == ref.shape
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel < 0.2, (policy, rel)
    # narrower-than-substrate codes: int4 on the exact int backend (on an
    # approx multiplier the ~constant absolute truncation error would swamp
    # the tiny int4 products — that pairing is legal but useless)
    out4 = sub.get_substrate("int8").dot_general(
        x, w, ContractionSpec.matmul(quant=QuantPolicy(bits=4)))
    rel = float(jnp.linalg.norm(out4 - ref) / jnp.linalg.norm(ref))
    assert 0 < rel < 0.5, rel


def test_pinned_scales_reproduce_dynamic():
    """Pinning the dynamically-derived scales gives the identical result —
    the scale-reuse contract the policy extraction exists for."""
    s = sub.get_substrate("approx_lut")
    x = jnp.asarray(RNG.normal(size=(4, 16)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))
    qm = 127.0
    x_scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qm
    w_scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / qm  # (N,)
    dyn = np.asarray(s.dot(x, w))
    pinned = np.asarray(s.dot_general(x, w, ContractionSpec.matmul(
        quant=QuantPolicy(x_scale=x_scale, w_scale=w_scale))))
    np.testing.assert_array_equal(pinned, dyn)
    # a pinned scale really is pinned: reusing it on a rescaled activation
    # tensor changes the output by exactly that rescaling of the codes
    half = np.asarray(s.dot_general(0.5 * x, w, ContractionSpec.matmul(
        quant=QuantPolicy(x_scale=x_scale, w_scale=w_scale))))
    assert not np.array_equal(half, dyn)


def test_quant_policy_validation():
    with pytest.raises(ValueError, match="x_mode"):
        QuantPolicy(x_mode="per_row")
    with pytest.raises(ValueError, match="bits"):
        QuantPolicy(bits=1)
    with pytest.raises(ValueError, match="eps"):
        QuantPolicy(eps=0.0)
    s = sub.get_substrate("approx_lut:proposed@4")
    x = jnp.ones((2, 8), jnp.float32)
    w = jnp.ones((8, 2), jnp.float32)
    with pytest.raises(ValueError, match="exceeds the substrate operand"):
        s.dot_general(x, w, ContractionSpec.matmul(quant=QuantPolicy(bits=8)))


# ---------------------------------------------------------------------------
# epsilon-guarded scale: zero activations / zero image regression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_zero_activations_give_zero_output(spec):
    """An all-zero activation tensor must produce finite (near-)zero output:
    the epsilon guard keeps the per-tensor scale from degenerating to 0/0.
    The approx backends' compensation constant (f(0,b) = +192 at N=8, true
    to the netlist) contributes only through the tiny guarded scale, so it
    vanishes below float precision instead of poisoning the output."""
    s = sub.get_substrate(spec)
    x = jnp.zeros((4, 16), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))
    out = np.asarray(s.dot(x, w))
    assert np.isfinite(out).all(), spec
    assert (np.abs(out) < 1e-6).all(), (spec, np.abs(out).max())
    if s.meta.name in ("exact", "int8"):
        assert (out == 0).all(), spec


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_zero_image_gives_zero_edge_map(spec):
    """Zero image → zero edge map through the quantized float path.

    (The integer netlist path intentionally differs: a zero pixel still
    fires the compensation constant — f(0,b)=+192 at N=8 — so the bit-true
    integer edge map of a black image is the constant response, preserved
    by the parity suite. The float path's epsilon-guarded per-tensor scale
    is what turns that constant bias into an exact-zero uint8 map.)"""
    s = sub.get_substrate(spec)
    imgs = jnp.zeros((2, 12, 12), jnp.float32)     # zero image, float domain
    patches = conv._im2col(imgs, 3, 3)             # (B, H, W, 9)
    kernel = jnp.asarray(conv.LAPLACIAN, jnp.float32).reshape(9, 1)
    out = np.asarray(s.dot_general(
        patches, kernel,
        ContractionSpec((((3,), (0,)), ((), ())), quant=QuantPolicy())))
    assert np.isfinite(out).all(), spec
    edge_map = np.clip(np.round(out[..., 0]), 0, 255).astype(np.uint8)
    assert (edge_map == 0).all(), (spec, np.abs(out).max())


# ---------------------------------------------------------------------------
# Partitioning: in-process (1-device mesh) behaviour + validation
# ---------------------------------------------------------------------------


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_partitioning_validation():
    mesh = _mesh1()
    with pytest.raises(ValueError, match="at least one"):
        Partitioning(mesh, m_axis=None, k_axis=None)
    with pytest.raises(ValueError, match="not a mesh axis"):
        Partitioning(mesh, m_axis="model")
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="must differ"):
        Partitioning(mesh2, m_axis="data", k_axis="data")


def test_partitioned_single_device_bit_identical():
    """The shard_map lowering itself (1-device mesh) changes nothing."""
    part = Partitioning(_mesh1(), m_axis="data")
    a = RNG.integers(-128, 128, (5, 19)).astype(np.int8)
    b = RNG.integers(-128, 128, (19, 3)).astype(np.int8)
    for spec in ("approx_bitexact", "approx_lut", "int8"):
        s = sub.get_substrate(spec)
        ref = np.asarray(s.dot_int(a, b))
        got = np.asarray(s.dot_general(
            jnp.asarray(a), jnp.asarray(b),
            ContractionSpec(partitioning=part)))
        np.testing.assert_array_equal(got, ref, err_msg=spec)


def test_partitioned_batch_dims_not_supported():
    part = Partitioning(_mesh1(), m_axis="data")
    a = jnp.zeros((2, 4, 8), jnp.int8)
    b = jnp.zeros((2, 8, 3), jnp.int8)
    with pytest.raises(NotImplementedError, match="batch dimensions"):
        sub.get_substrate("approx_bitexact").dot_general(
            a, b, ContractionSpec((((2,), (1,)), ((0,), (0,))),
                                  partitioning=part))


def test_partitioning_scope_is_ambient():
    assert sub.current_partitioning() is None
    p = Partitioning(_mesh1(), m_axis="data")
    with sub.partitioning_scope(p):
        assert sub.current_partitioning() is p
        with sub.partitioning_scope(None):
            assert sub.current_partitioning() is None
        assert sub.current_partitioning() is p
    assert sub.current_partitioning() is None


# ---------------------------------------------------------------------------
# sharded parity on 8 forced host devices (subprocess harness)
# ---------------------------------------------------------------------------


def test_sharded_bit_identity_8_devices():
    """shard_map dot_general == unsharded dot_int bit-exactly on a (2, 4)
    mesh: data-parallel M, reduce-scattered K, per-K-shard f(0,0)
    correction. Covers non-divisible M and K (zero-pad + global f(0,0)
    fix-up — design_strollo2020 has a different f(0,0) than proposed, so a
    wrong-constant bug cannot cancel) and the psum fallback when N doesn't
    divide the k axis."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import substrate as sub
        from repro.nn.substrate import ContractionSpec, Partitioning

        rng = np.random.default_rng(3)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        parts = [
            Partitioning(mesh, m_axis="data"),                  # M only
            Partitioning(mesh, m_axis=None, k_axis="model"),    # K only
            Partitioning(mesh, m_axis="data", k_axis="model"),  # M + K
        ]
        shapes = [
            (8, 32, 8),    # everything divides; psum_scatter path
            (5, 19, 3),    # M, K, N all non-divisible; psum fallback
            (16, 64, 4),   # N == k_shards; psum_scatter path
        ]
        specs = ("exact", "int8", "approx_bitexact",
                 "approx_bitexact:design_strollo2020", "approx_lut",
                 "approx_lut:csp_axc1@4")
        for spec in specs:
            s = sub.get_substrate(spec)
            for m, k, n in shapes:
                a = rng.integers(-128, 128, (m, k)).astype(np.int8)
                b = rng.integers(-128, 128, (k, n)).astype(np.int8)
                ref = np.asarray(s.dot_int(a, b))
                for part in parts:
                    got = np.asarray(s.dot_general(
                        jnp.asarray(a), jnp.asarray(b),
                        ContractionSpec(partitioning=part)))
                    np.testing.assert_array_equal(
                        got, ref,
                        err_msg=f"{spec} {(m, k, n)} m={part.m_axis} "
                                f"k={part.k_axis}")
        print("sharded parity ok", len(specs) * len(shapes) * len(parts))
    """)
    assert "sharded parity ok 54" in out


def test_sharded_quantized_float_path_8_devices():
    """The full QuantPolicy float path under a Partitioning equals the
    unsharded float dot bit-exactly for the integer-exact backends (int32
    partial sums reduce exactly; the scales are computed unsharded)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import substrate as sub
        from repro.nn.substrate import ContractionSpec, Partitioning, \\
            QuantPolicy

        rng = np.random.default_rng(5)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        part = Partitioning(mesh, m_axis="data", k_axis="model")
        x = jnp.asarray(rng.normal(size=(6, 40)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
        for spec in ("int8", "approx_bitexact", "approx_lut"):
            s = sub.get_substrate(spec)
            ref = np.asarray(s.dot(x, w))
            got = np.asarray(s.dot_general(x, w, ContractionSpec.matmul(
                quant=QuantPolicy(), partitioning=part)))
            np.testing.assert_array_equal(got, ref, err_msg=spec)
        # exact float: psum reduction order => allclose, not bit-identity
        e = sub.get_substrate("exact")
        got = np.asarray(e.dot_general(x, w, ContractionSpec.matmul(
            quant=QuantPolicy(), partitioning=part)))
        np.testing.assert_allclose(got, np.asarray(e.dot(x, w)),
                                   rtol=1e-5, atol=1e-5)
        print("sharded float ok")
    """)
    assert "sharded float ok" in out


def test_sharded_stat_requires_divisible_k():
    """approx_stat's contraction-level correction is not separable per
    product, so the k-pad f(0,0) fix-up can't apply — loud error."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import substrate as sub
        from repro.nn.substrate import ContractionSpec, Partitioning

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        part = Partitioning(mesh, m_axis="data", k_axis="model")
        s = sub.get_substrate("approx_stat")
        a = jnp.zeros((4, 19), jnp.int8)   # K=19 not divisible by 4
        b = jnp.zeros((19, 4), jnp.int8)
        try:
            s.dot_general(a, b, ContractionSpec(partitioning=part))
            raise SystemExit("expected ValueError")
        except ValueError as e:
            assert "scalar_faithful" in str(e), e
        # divisible K works (contraction-level rounding may differ per
        # shard, so compare against tolerance, not bit-identity)
        a = jnp.asarray(np.random.default_rng(0).integers(-128, 128, (4, 32)),
                        jnp.int8)
        b = jnp.asarray(np.random.default_rng(1).integers(-128, 128, (32, 4)),
                        jnp.int8)
        ref = np.asarray(s.dot_int(a, b), np.int64)
        got = np.asarray(s.dot_general(a, b,
                                       ContractionSpec(partitioning=part)),
                         np.int64)
        assert np.abs(got - ref).max() <= 4, np.abs(got - ref).max()
        print("stat sharded ok")
    """)
    assert "stat sharded ok" in out
