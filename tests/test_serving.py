"""Serving subsystem: scheduling primitives, telemetry, the micro-batched
edge-detection service (bit-identical to ``edge_detect_batched`` on every
registered substrate), and the LM engine on the shared SlotScheduler."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import image_batch, mixed_shape_batch
from repro.models import registry as reg
from repro.nn import conv
from repro.nn import substrate as sub
from repro.serving import (EdgeDetectService, MicroBatcher, Request,
                           ServingEngine, ServingMetrics, SlotScheduler)
from tests.test_models_smoke import reduced
from tests.test_substrates import _tiny_cfg


# ---------------------------------------------------------------------------
# SlotScheduler (shared LM/vision scheduling core)
# ---------------------------------------------------------------------------


def test_slot_scheduler_refill_release_cycle():
    s = SlotScheduler(2)
    s.extend(["a", "b", "c"])
    assert s.refill() == [(0, "a"), (1, "b")]
    assert s.occupancy == 2 and s.busy and s.refill() == []
    s.release(0)
    assert s.refill() == [(0, "c")]
    assert [i for i, _ in s.occupied()] == [0, 1]
    s.release(0)
    s.release(1)
    assert not s.busy and s.occupancy == 0


def test_slot_scheduler_rejects_zero_slots():
    with pytest.raises(ValueError, match="n_slots"):
        SlotScheduler(0)


# ---------------------------------------------------------------------------
# MicroBatcher flush policy
# ---------------------------------------------------------------------------


def _echo_batcher(calls, **kw):
    def process(bucket, payloads):
        calls.append((bucket, list(payloads)))
        return [p * 10 for p in payloads]
    return MicroBatcher(process, **kw)


class FakeClock:
    """Injectable deterministic clock for timeout-policy tests.

    ``advance`` moves virtual time and wakes the batcher's workers (they
    block in ``cv.wait`` with a timeout computed from this clock), so
    timeout flushes fire exactly when the test says time has passed — no
    wall-clock sleeps, no flakes on slow machines."""

    def __init__(self):
        self.t = 0.0
        self._batcher = None

    def attach(self, batcher: MicroBatcher) -> MicroBatcher:
        self._batcher = batcher
        return batcher

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt
        if self._batcher is not None:
            with self._batcher._cv:
                self._batcher._cv.notify_all()


def test_flush_on_size_before_timeout():
    """A full bucket flushes immediately even with a huge max_wait."""
    calls = []
    with _echo_batcher(calls, max_batch_size=3, max_wait_s=60.0) as b:
        tickets = b.submit_many([1, 2, 3])
        assert [t.result(timeout=10.0) for t in tickets] == [10, 20, 30]
    assert calls == [(None, [1, 2, 3])]
    assert b.metrics.batches_by_reason == {"size": 1}
    assert b.metrics.occupancy_hist == {3: 1}


def test_flush_on_timeout_partial_batch():
    """A partial bucket flushes once its oldest request expires.

    Virtual time (FakeClock) — both submissions land at t=0, nothing may
    flush until the clock passes max_wait_s, then exactly one timeout
    batch fires. Deterministic on any machine."""
    calls = []
    clock = FakeClock()
    with clock.attach(_echo_batcher(calls, max_batch_size=8, max_wait_s=1.0,
                                    clock=clock)) as b:
        tickets = b.submit_many([1, 2])
        clock.advance(0.5)                       # before the deadline
        assert not any(t.done() for t in tickets)
        clock.advance(0.6)                       # past max_wait_s
        assert [t.result(timeout=10.0) for t in tickets] == [10, 20]
    assert calls == [(None, [1, 2])]
    assert b.metrics.batches_by_reason == {"timeout": 1}
    assert b.metrics.occupancy_hist == {2: 1}
    # latencies are measured on the injected clock: exact, not approximate
    assert all(t.latency_s == pytest.approx(1.1) for t in tickets)


def test_bucket_isolation_and_sync_flush():
    """Buckets never mix inside a batch; flush() drains without a worker."""
    calls = []
    b = _echo_batcher(calls, max_batch_size=2, max_wait_s=60.0,
                      bucket_fn=lambda p: p % 2)
    tickets = b.submit_many([0, 1, 2, 3, 4])   # evens bucket 0, odds bucket 1
    assert b.depth == 5
    b.flush()
    assert [t.result(timeout=0) for t in tickets] == [0, 10, 20, 30, 40]
    for bucket, payloads in calls:
        assert {p % 2 for p in payloads} == {bucket}
    sizes = sorted(len(p) for _, p in calls)
    assert sizes == [1, 2, 2] and b.depth == 0
    assert b.metrics.batches_by_reason["size"] == 2   # two full pairs
    assert b.metrics.batches_by_reason["drain"] == 1  # the odd one out


def test_stop_drains_queue():
    calls = []
    b = _echo_batcher(calls, max_batch_size=8, max_wait_s=60.0).start()
    t = b.submit(7)
    b.stop(drain=True)
    assert t.result(timeout=0) == 70
    assert b.metrics.batches_by_reason == {"drain": 1}


def test_expired_bucket_not_starved_by_full_bucket():
    """Oldest flushable head wins: a continuously-full hot bucket must not
    preempt another bucket whose head has exceeded max_wait_s."""
    t = [0.0]
    calls = []
    b = MicroBatcher(lambda k, ps: [p for p in ps], max_batch_size=2,
                     max_wait_s=0.01, bucket_fn=lambda p: p[0],
                     clock=lambda: t[0])
    b.submit(("cold", 0))
    t[0] = 0.02                                   # cold head now expired
    b.submit(("hot", 1))
    b.submit(("hot", 2))                          # hot bucket is full
    ready = b._pop_ready_locked(t[0], drain=False)
    assert ready is not None
    key, batch, reason = ready
    assert key == "cold" and reason == "timeout" and len(batch) == 1


def test_submit_after_stop_raises():
    """A post-stop ticket would never be served — submit must fail fast."""
    b = _echo_batcher([], max_batch_size=2, max_wait_s=60.0).start()
    b.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        b.submit(1)
    # restartable: start() clears the stopped state
    t = b.start().submit(2)
    b.stop(drain=True)
    assert t.result(timeout=0) == 20


def test_process_error_propagates_to_every_ticket():
    def boom(bucket, payloads):
        raise RuntimeError("kernel exploded")
    b = MicroBatcher(boom, max_batch_size=2, max_wait_s=60.0)
    tickets = b.submit_many([1, 2])
    b.flush()
    for t in tickets:
        with pytest.raises(RuntimeError, match="kernel exploded"):
            t.result(timeout=0)
    assert b.metrics.requests_failed == 2 and b.metrics.requests_served == 0


def test_concurrent_submitters_all_served():
    results = {}
    with _echo_batcher([], max_batch_size=4, max_wait_s=0.001) as b:
        def client(i):
            results[i] = b.submit(i).result(timeout=10.0)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert results == {i: i * 10 for i in range(16)}
    assert b.metrics.requests_served == 16


# ---------------------------------------------------------------------------
# ServingMetrics
# ---------------------------------------------------------------------------


def test_metrics_counters_and_percentiles():
    m = ServingMetrics()
    for d in (3, 5, 2):
        m.record_enqueue(d)
    m.record_batch(3, "size", 4)
    m.record_batch(1, "timeout", 4)
    for lat in np.linspace(0.001, 0.1, 100):
        m.record_done(float(lat), depth=0)
    s = m.snapshot()
    assert s["requests_enqueued"] == 3 and s["requests_served"] == 100
    assert s["queue_depth_peak"] == 5 and s["queue_depth"] == 0
    assert s["batches_by_reason"] == {"size": 1, "timeout": 1}
    assert s["occupancy_hist"] == {1: 1, 3: 1}
    assert s["mean_occupancy"] == pytest.approx(0.5)
    assert s["latency_p50_ms"] == pytest.approx(50.5, rel=0.03)
    assert s["latency_p99_ms"] == pytest.approx(99.0, rel=0.03)
    assert s["latency_p95_ms"] <= s["latency_p99_ms"]
    assert m.throughput() > 0
    assert "p50=" in m.format_table()


def test_metrics_reset_zeroes_everything():
    m = ServingMetrics()
    m.record_enqueue(1)
    m.record_batch(2, "size", 2)
    m.record_done(0.5)
    m.reset()
    s = m.snapshot()
    assert s["requests_enqueued"] == 0 and s["batches_flushed"] == 0
    assert s["latency_p50_ms"] == 0.0 and s["occupancy_hist"] == {}


# ---------------------------------------------------------------------------
# EdgeDetectService: bit-identical to direct edge_detect_batched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", sorted(sub.list_substrates()))
def test_edge_service_bit_identical_per_substrate(spec):
    """Mixed-shape requests through bucketing/padding == direct pipeline."""
    imgs = mixed_shape_batch(5, shapes=((8, 8), (12, 10), (16, 16)), seed=2)
    svc = EdgeDetectService(spec, max_batch_size=2, max_wait_s=1e-3,
                            bucket_granularity=8)
    try:
        outs = svc.detect(imgs)
    finally:
        svc.close()
    for im, out in zip(imgs, outs):
        ref = np.asarray(conv.edge_detect_batched(im[None], spec))[0]
        assert out.shape == im.shape and out.dtype == np.uint8
        np.testing.assert_array_equal(out, ref, err_msg=f"{spec} {im.shape}")
    assert svc.metrics.requests_served == len(imgs)


def test_edge_service_non_proposed_pallas_spec_parity():
    """The generated closed-form Pallas kernel behind a full spec
    (wiring@width) serves bit-identically to the direct pipeline — the
    service carries any approx_pallas spec, not just proposed@8."""
    spec = "approx_pallas:design_strollo2020@4"
    imgs = mixed_shape_batch(4, shapes=((8, 8), (12, 10)), seed=4)
    svc = EdgeDetectService(spec, max_batch_size=2, max_wait_s=1e-3,
                            bucket_granularity=8)
    try:
        outs = svc.detect(imgs)
    finally:
        svc.close()
    assert svc.substrate.meta.cost_hint == "vpu"  # generated closed form
    for im, out in zip(imgs, outs):
        ref = np.asarray(conv.edge_detect_batched(im[None], spec))[0]
        np.testing.assert_array_equal(out, ref, err_msg=f"{spec} {im.shape}")


def test_edge_service_shape_bucket_isolation():
    """Images of different bucket shapes never share a flush."""
    svc = EdgeDetectService("exact", max_batch_size=8, max_wait_s=60.0,
                            bucket_granularity=8, start=False)
    svc.batcher.submit_many(mixed_shape_batch(
        6, shapes=((8, 8), (16, 16), (8, 8), (16, 16), (8, 8), (16, 16))))
    svc.batcher.flush()
    svc.close()
    # two buckets → two drain flushes of 3, despite room for 8
    assert svc.metrics.batches_flushed == 2
    assert svc.metrics.occupancy_hist == {3: 2}
    assert set(svc.compiled_shapes) == {(8, 8, 8), (8, 16, 16)}


def test_edge_service_flush_on_size_vs_drain():
    """5 images at max_batch 2: two full batches flush on size, the
    leftover is drained at close. max_wait is effectively infinite so the
    reason split never depends on wall-clock timing."""
    svc = EdgeDetectService("exact", max_batch_size=2, max_wait_s=60.0)
    try:
        tickets = [svc.submit(im) for im in image_batch(5, 16, 16)]
        full = [t.result(timeout=30.0) for t in tickets[:4]]  # size flushes
        assert all(o.shape == (16, 16) for o in full)
    finally:
        svc.close()                                # drains the leftover
    assert tickets[4].result(timeout=0).shape == (16, 16)
    assert svc.metrics.batches_by_reason == {"size": 2, "drain": 1}


def test_edge_service_compiled_call_cache_stable():
    """Same bucket shape served twice compiles once (batch-dim padding)."""
    svc = EdgeDetectService("exact", max_batch_size=4, max_wait_s=1e-3)
    try:
        svc.detect(image_batch(3, 16, 16))          # partial batch
        svc.detect(image_batch(4, 16, 16))          # full batch, same bucket
    finally:
        svc.close()
    assert svc.metrics.compiled_calls == 1
    assert svc.compiled_shapes == ((4, 16, 16),)


def test_edge_service_noise_and_uint8_roundtrip():
    imgs = image_batch(4, 16, 16, noise=8.0)
    assert imgs.dtype == np.uint8
    assert not np.array_equal(imgs, image_batch(4, 16, 16))
    svc = EdgeDetectService("approx_lut", max_batch_size=4, max_wait_s=1e-3)
    try:
        outs = svc.detect(imgs)
    finally:
        svc.close()
    ref = np.asarray(conv.edge_detect_batched(imgs, "approx_lut"))
    np.testing.assert_array_equal(np.stack(outs), ref)


def test_edge_service_rejects_bad_inputs():
    svc = EdgeDetectService("exact", start=False)
    with pytest.raises(ValueError, match="uint8"):
        svc.submit(np.zeros((4, 4), np.float32))
    with pytest.raises(ValueError, match="single"):
        svc.submit(np.zeros((2, 4, 4), np.uint8))
    with pytest.raises(ValueError, match="bucket_granularity"):
        EdgeDetectService("exact", bucket_granularity=0)


def test_mixed_shape_batch_generator():
    imgs = mixed_shape_batch(7, seed=1, noise=3.0)
    assert len(imgs) == 7
    assert len({im.shape for im in imgs}) > 1
    assert all(im.dtype == np.uint8 and im.ndim == 2 for im in imgs)
    with pytest.raises(ValueError, match="non-empty"):
        mixed_shape_batch(2, shapes=())


# ---------------------------------------------------------------------------
# LM ServingEngine (on the shared SlotScheduler)
# ---------------------------------------------------------------------------


def _lm_bundle(seed=0):
    cfg = reduced("minitron-8b", n_layers=1, d_model=32, d_ff=64, vocab=64,
                  n_heads=2, n_kv_heads=2)
    bundle = reg._BUILDERS[cfg.family](cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(seed))


def test_serving_engine_generates():
    bundle, params = _lm_bundle()
    eng = ServingEngine(bundle, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=[1, 2, 3], max_tokens=5),
            Request(prompt=[4, 5], max_tokens=4, temperature=0.7)]
    out = eng.generate(reqs)
    assert len(out[0].output) == 5 and len(out[1].output) == 4
    assert all(0 <= t < 64 for t in out[0].output + out[1].output)
    # the engine reports through the shared metrics schema
    assert eng.metrics.requests_served == 2
    assert eng.metrics.batches_by_reason.keys() == {"decode"}
    assert eng.metrics.latency_percentile(50) > 0


def test_serving_engine_redundant_generate_is_noop():
    """Re-submitting already-done requests must not spin decode steps."""
    bundle, params = _lm_bundle()
    eng = ServingEngine(bundle, params, batch_size=2, max_len=64)
    reqs = [Request(prompt=[1, 2], max_tokens=3)]
    eng.generate(reqs)
    steps = eng.metrics.batches_flushed
    eng.generate(reqs)                   # all requests already done
    assert eng.metrics.batches_flushed == steps


def test_serving_engine_truncated_request_counts_failed():
    """A request cut off by the max_len horizon lands in requests_failed."""
    bundle, params = _lm_bundle()
    eng = ServingEngine(bundle, params, batch_size=1, max_len=8)
    out = eng.generate([Request(prompt=[1, 2, 3], max_tokens=50)])[0]
    assert 0 < len(out.output) < 50      # truncated, not fully served
    assert eng.metrics.requests_failed == 1
    assert eng.metrics.requests_served == 0


def test_serving_greedy_matches_decode_loop():
    """Engine greedy output == manual decode_step loop (same caches)."""
    bundle, params = _lm_bundle(seed=3)
    prompt = [5, 9, 11]

    eng = ServingEngine(bundle, params, batch_size=1, max_len=32)
    out = eng.generate([Request(prompt=prompt, max_tokens=4)])[0].output

    state = bundle.init_decode_state(1, 32)
    toks = list(prompt)
    outs = []
    for i in range(len(prompt) + 3):
        tok = toks[i] if i < len(prompt) else outs[-1]
        batch = {"token": jnp.asarray([[tok]], jnp.int32),
                 "cache_len": jnp.asarray(i, jnp.int32)}
        logits, state = jax.jit(bundle.decode_step)(params, state, batch)
        if i >= len(prompt) - 1:
            outs.append(int(np.asarray(logits[0, 0]).argmax()))
    assert out == outs[:4], (out, outs)


def test_serving_engine_substrate_override():
    bundle = reg.build_bundle(_tiny_cfg())
    assert bundle.cfg.dot_mode == "exact"
    params = bundle.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(bundle, params, batch_size=1, max_len=32,
                        substrate="int8")
    assert eng.cfg.dot_mode == "int8"
    assert eng.bundle.substrate is sub.get_substrate("int8")
    out = eng.generate([Request(prompt=[1, 2, 3], max_tokens=4)])
    assert len(out[0].output) == 4
    assert all(0 <= t < eng.cfg.vocab for t in out[0].output)


def test_serving_engine_accepts_registry_instance_rejects_custom():
    bundle = reg.build_bundle(_tiny_cfg())
    params = bundle.init_params(jax.random.PRNGKey(0))
    # a registry-produced instance is accepted and resolves to its spec
    eng = ServingEngine(bundle, params, batch_size=1, max_len=16,
                        substrate=sub.get_substrate("approx_lut"))
    assert eng.cfg.dot_mode == "approx_lut:proposed"

    # a custom (unregistered) subclass would be silently swapped out by the
    # spec-string model path, so the engine must refuse it
    class Custom(sub.LutSubstrate):
        pass

    with pytest.raises(ValueError, match="does not match the registered"):
        ServingEngine(bundle, params, batch_size=1, max_len=16,
                      substrate=Custom("proposed"))
