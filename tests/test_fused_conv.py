"""Fused conv kernel, generated closed-form wirings, and k-slab vectorization.

The acceptance gates of the fused pipeline PR:
 * ``make_closed_form`` reproduces ``core.multiplier`` bit-exactly for every
   registered wiring (exhaustive at N=4, sampled at other widths);
 * the vectorized k-slab matmul kernels (``k_chunk > 1``) match both the
   ``k_chunk=1`` fori-equivalent body and the bit-exact substrate;
 * ``conv2d_batched(..., fused=True)`` is bit-identical to the im2col
   reference path across substrates × wirings × widths, including ragged
   H/W, NHWC, and the traced-kernel fallback.

Everything here runs in interpret mode off-TPU, so images stay small.
CI smoke selection: ``-k "fused and n4"``.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.core import metrics
from repro.core import multiplier as mult
from repro.kernels import blocking
from repro.kernels.closed_form import (approx_product_i32, closed_form_f00,
                                       make_closed_form)
from repro.kernels.approx_matmul.kernel import resolve_k_chunk
from repro.kernels.approx_matmul.ops import closed_form_matmul
from repro.kernels.lut_matmul.ops import lut_matmul
from repro.kernels.fused_conv.ops import KERNEL_KINDS, fused_conv2d
from repro.kernels.fused_conv.ref import fused_conv_ref
from repro.nn import conv
from repro.nn import substrate as sub

RNG = np.random.default_rng(66)


def _img(h, w, lo=-128, hi=128):
    return RNG.integers(lo, hi, (h, w)).astype(np.int32)


def _pair_grid(n):
    lo, hi = -(1 << (n - 1)), 1 << (n - 1)
    v = np.arange(lo, hi, dtype=np.int32)
    return v[:, None], v[None, :]


# ---------------------------------------------------------------------------
# generated closed-form kernels vs the core model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(mult.WIRINGS))
def test_closed_form_generator_exhaustive_n4(name):
    """Every registered wiring's generated kernel is bit-exact at N=4."""
    a, b = metrics.operand_grid(4)
    want = np.asarray(mult.make_multiplier(name, 4)(a, b))
    got = np.asarray(make_closed_form(name, 4)(a, b))
    np.testing.assert_array_equal(got, want, err_msg=name)


def test_closed_form_generator_matches_handwritten_n8():
    """The generated proposed@8 kernel equals the hand-derived closed form
    (and the core model) on the exhaustive 8-bit grid."""
    a, b = metrics.operand_grid(8)
    want = np.asarray(mult.approx_multiply(a, b))
    gen = np.asarray(make_closed_form("proposed")(a, b))
    hand = np.asarray(approx_product_i32(a, b))
    np.testing.assert_array_equal(gen, want)
    np.testing.assert_array_equal(gen, hand)


@pytest.mark.parametrize("name", ["proposed", "csp_axc1", "design_du2022",
                                  "design_strollo2020"])
@pytest.mark.parametrize("width", [3, 4, 5, 6, 7, 8])
def test_closed_form_generator_widths(name, width):
    """Sampled parity at widths 3–8, with out-of-range operands (the
    generated kernel wraps into the width's domain like the core model)."""
    fn = make_closed_form(name, width)
    ref = mult.make_multiplier(mult.WIRING_ALIASES.get(name, name), width)
    a = RNG.integers(-300, 300, (64,)).astype(np.int32)
    b = RNG.integers(-300, 300, (64,)).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(fn(a, b)), np.asarray(ref(a, b)))


@pytest.mark.parametrize("key", ["proposed", "proposed@4", "csp_axc1@5",
                                 "design_strollo2020"])
def test_closed_form_f00_matches_lut_f00(key):
    assert closed_form_f00(key) == lut_lib.f00(key)


# ---------------------------------------------------------------------------
# vectorized k-slab vs the fori-equivalent body (k_chunk=1) vs bitexact
# ---------------------------------------------------------------------------

def test_resolve_k_chunk_divides_block():
    assert resolve_k_chunk(8, 128) == 8
    assert resolve_k_chunk(8, 12) == 4   # gcd fallback keeps it valid
    assert resolve_k_chunk(5, 8) == 1
    assert resolve_k_chunk(0, 128) == 128  # gcd(0, bk): whole block at once


@pytest.mark.parametrize("name", sorted(mult.WIRINGS))
def test_kslab_closed_form_exhaustive_n4(name):
    """Vectorized (k_chunk=8) and fori-equivalent (k_chunk=1) closed-form
    matmuls agree with the bit-exact substrate on the exhaustive N=4 grid
    (K=1 forces pad correction)."""
    a, b = _pair_grid(4)
    want = np.asarray(
        sub.get_substrate(f"approx_bitexact:{name}@4").dot_int8(a, b))
    for kc in (8, 1):
        got = np.asarray(closed_form_matmul(a, b, f"{name}@4", k_chunk=kc))
        np.testing.assert_array_equal(got, want, err_msg=f"{name} kc={kc}")


def test_kslab_lut_exhaustive_n4():
    a, b = _pair_grid(4)
    flat = lut_lib.flat_lut("proposed@4")
    want = np.asarray(lut_matmul(a, b, flat, k_chunk=1))
    got = np.asarray(lut_matmul(a, b, flat, k_chunk=8))
    np.testing.assert_array_equal(got, want)


def test_kslab_ragged_k_padding():
    """k_chunk survives K that isn't a multiple of the chunk or block."""
    a = _img(9, 37)
    b = _img(37, 11)
    want = np.asarray(sub.get_substrate("approx_bitexact").dot_int(a, b))
    for kc in (1, 4, 8):
        got = np.asarray(closed_form_matmul(a, b, "proposed", k_chunk=kc))
        np.testing.assert_array_equal(got, want, err_msg=f"kc={kc}")


# ---------------------------------------------------------------------------
# fused conv vs the im2col reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(mult.WIRINGS))
def test_fused_conv_wirings_n4(name):
    """CI smoke gate: fused kernel == im2col path for every wiring at N=4."""
    imgs = np.stack([_img(13, 17, lo=-8, hi=8) for _ in range(2)])
    s = sub.get_substrate(f"approx_pallas:{name}@4")
    got = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=True))
    ref = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=False))
    np.testing.assert_array_equal(got, ref, err_msg=name)


@pytest.mark.parametrize("width", [3, 4, 5, 6, 7, 8])
def test_fused_conv_widths(width):
    imgs = _img(11, 19, lo=-(1 << (width - 1)), hi=1 << (width - 1))[None]
    s = sub.get_substrate(f"approx_pallas:proposed@{width}")
    got = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=True))
    ref = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=False))
    np.testing.assert_array_equal(got, ref, err_msg=f"width={width}")


@pytest.mark.parametrize("shape", [(1, 1), (3, 3), (5, 9), (13, 17),
                                   (20, 7), (33, 65)])
def test_fused_conv_ragged_shapes(shape):
    imgs = _img(*shape)[None]
    got = np.asarray(fused_conv2d(imgs, conv.LAPLACIAN, "proposed"))
    ref = np.asarray(fused_conv_ref(imgs, conv.LAPLACIAN, "proposed"))
    np.testing.assert_array_equal(got, ref, err_msg=str(shape))


@pytest.mark.parametrize("kern", [np.ones((1, 1), np.int32),
                                  RNG.integers(-4, 5, (2, 3)).astype(np.int32),
                                  RNG.integers(-4, 5, (5, 5)).astype(np.int32)])
def test_fused_conv_kernel_shapes(kern):
    """Odd, even, and 1x1 kernel dims all contract the same taps."""
    imgs = _img(10, 14)[None]
    got = np.asarray(fused_conv2d(imgs, kern, "proposed"))
    ref = np.asarray(fused_conv_ref(imgs, kern, "proposed"))
    np.testing.assert_array_equal(got, ref, err_msg=str(kern.shape))


@pytest.mark.parametrize("kind", KERNEL_KINDS)
def test_fused_conv_kernel_kinds(kind):
    """Both fused product strategies (generated closed form, flat LUT)
    produce the same bits."""
    imgs = _img(9, 12)[None]
    got = np.asarray(
        fused_conv2d(imgs, conv.LAPLACIAN, "csp_axc1@4", kernel_kind=kind))
    ref = np.asarray(fused_conv_ref(imgs, conv.LAPLACIAN, "csp_axc1@4"))
    np.testing.assert_array_equal(got, ref, err_msg=kind)


def test_fused_conv_exact_wiring_uses_lut():
    """'exact' has no CSP closed form — the fused path serves it via the
    flat LUT strategy. In-domain operands *and taps* only: the exact
    scalar model is a plain multiply and doesn't wrap out-of-range ints
    like the LUT does (conv.LAPLACIAN's center tap 8 is outside the
    signed 4-bit domain, so the 4-center discrete Laplacian is used)."""
    imgs = _img(8, 9, lo=-8, hi=8)[None]
    kern = np.array([[0, -1, 0], [-1, 4, -1], [0, -1, 0]], np.int32)
    got = np.asarray(fused_conv2d(imgs, kern, "exact@4"))
    ref = np.asarray(fused_conv_ref(imgs, kern, "exact@4"))
    np.testing.assert_array_equal(got, ref)


def test_fused_conv_nhwc():
    imgs = RNG.integers(-32, 32, (2, 9, 11, 3)).astype(np.int32)
    s = sub.get_substrate("approx_pallas:proposed@4")
    got = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=True))
    ref = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=False))
    np.testing.assert_array_equal(got, ref)


def test_fused_conv_traced_kernel_falls_back():
    """A traced kernel can't specialize the fused kernel — the auto gate
    silently takes the im2col path inside jit, still bit-identical."""
    imgs = _img(7, 9)[None]
    s = sub.get_substrate("approx_pallas:proposed@4")

    @jax.jit
    def run(k):
        return conv.conv2d_batched(imgs, k, s)

    got = np.asarray(run(jnp.asarray(conv.LAPLACIAN)))
    ref = np.asarray(conv.conv2d_batched(imgs, conv.LAPLACIAN, s, fused=False))
    np.testing.assert_array_equal(got, ref)


def test_fused_conv_edge_detect_batched_parity():
    """End to end: the batched edge pipeline through approx_pallas (which
    auto-selects the fused kernel) matches approx_bitexact."""
    imgs = RNG.integers(0, 256, (2, 16, 20)).astype(np.uint8)
    got = np.asarray(conv.edge_detect_batched(imgs, "approx_pallas:proposed@4"))
    ref = np.asarray(
        conv.edge_detect_batched(imgs, "approx_bitexact:proposed@4"))
    np.testing.assert_array_equal(got, ref)


def test_fused_true_requires_fused_capable_substrate():
    imgs = _img(6, 6)[None]
    with pytest.raises(ValueError, match="no fused conv"):
        conv.conv2d_batched(imgs, conv.LAPLACIAN, "approx_bitexact",
                            fused=True)


def test_fused_true_rejects_partitioning():
    imgs = _img(6, 6)[None]
    s = sub.get_substrate("approx_pallas:proposed@4")
    with pytest.raises(ValueError, match="incompatible with partitioning"):
        conv.conv2d_batched(imgs, conv.LAPLACIAN, s,
                            partitioning=object(), fused=True)


def test_fused_conv_rejects_bad_kernel_kind():
    imgs = _img(6, 6)[None]
    with pytest.raises(ValueError):
        fused_conv2d(imgs, conv.LAPLACIAN, "proposed", kernel_kind="mxu")


# ---------------------------------------------------------------------------
# interpret-mode resolution
# ---------------------------------------------------------------------------

def test_resolve_interpret_precedence(monkeypatch):
    monkeypatch.delenv(blocking.INTERPRET_ENV, raising=False)
    default = jax.default_backend() != "tpu"
    assert blocking.resolve_interpret() is default
    # explicit param always wins
    assert blocking.resolve_interpret(True) is True
    assert blocking.resolve_interpret(False) is False
    # env overrides the backend default, but not the explicit param
    monkeypatch.setenv(blocking.INTERPRET_ENV, "0")
    assert blocking.resolve_interpret() is False
    assert blocking.resolve_interpret(True) is True
    monkeypatch.setenv(blocking.INTERPRET_ENV, "yes")
    assert blocking.resolve_interpret() is True
    monkeypatch.setenv(blocking.INTERPRET_ENV, "bogus")
    with pytest.raises(ValueError, match=blocking.INTERPRET_ENV):
        blocking.resolve_interpret()
