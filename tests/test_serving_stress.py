"""Concurrency stress tests for the multi-worker serving layer.

What "correct under concurrency" means for the MicroBatcher, checked
across worker counts 1/2/4 with multiple producer threads:

* **no lost tickets** — every submitted request is served (or carries an
  error); nothing blocks forever;
* **no duplicated work** — each payload is processed exactly once across
  all batches (isolation retries excepted, and only on failures);
* **no cross-wiring** — a ticket's result embeds the nonce of *its own*
  payload, never a neighbour's;
* **batch homogeneity** — payloads inside one batch always share the
  bucket key;
* **accounting closure** — flush-reason counters sum to the number of
  batches actually processed, and per-worker batch counters sum to the
  same total;
* **clean shutdown** — ``stop(drain=True)`` with a full queue serves
  everything and leaks no worker threads (``threading.enumerate()``);
* **fault isolation** — a poison payload fails only its own ticket, the
  batch's healthy tickets are still served, the worker loop survives to
  serve later submissions, and ``serving_worker_errors_total`` counts it.

Every wait uses events/``Ticket.result(timeout=...)`` — no sleeps, no
wall-clock assertions. A hypothesis stateful machine (skip-guarded: the
dependency is optional) drives random submit/flush/stop interleavings
against the same invariants.
"""
import random
import threading

import numpy as np
import pytest

from repro.data import mixed_shape_batch
from repro.nn import conv
from repro.serving import EdgeDetectService, MicroBatcher

WORKER_COUNTS = (1, 2, 4)


def _batcher_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("micro-batcher")]


# ---------------------------------------------------------------------------
# producer threads x buckets x workers: completeness, wiring, accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_workers", WORKER_COUNTS)
def test_stress_no_lost_duplicated_or_crosswired_tickets(n_workers):
    n_producers, per_producer = 4, 40
    buckets = ("a", "b", "c")
    lock = threading.Lock()
    batches = []                      # (key, [nonce, ...]) per process call

    def process(key, payloads):
        for bucket, _nonce in payloads:
            assert bucket == key, "bucket mixed into foreign batch"
        with lock:
            batches.append((key, [n for _, n in payloads]))
        return [("served", key, nonce) for _, nonce in payloads]

    before = _batcher_threads()
    b = MicroBatcher(process, max_batch_size=4, max_wait_s=1e-4,
                     bucket_fn=lambda p: p[0], n_workers=n_workers).start()
    tickets = {}
    t_lock = threading.Lock()
    barrier = threading.Barrier(n_producers)

    def produce(pid):
        rng = random.Random(pid)
        barrier.wait()                # maximum contention at the start
        for i in range(per_producer):
            nonce = (pid, i)
            t = b.submit((rng.choice(buckets), nonce))
            with t_lock:
                tickets[nonce] = t

    producers = [threading.Thread(target=produce, args=(pid,))
                 for pid in range(n_producers)]
    for t in producers:
        t.start()
    for t in producers:
        t.join()

    # completeness + wiring: each ticket returns its own nonce
    for nonce, t in tickets.items():
        tag, key, got = t.result(timeout=30.0)
        assert tag == "served" and got == nonce, \
            f"ticket {nonce} got result for {got}"
    b.stop()

    total = n_producers * per_producer
    assert len(tickets) == total
    # no duplicated/lost work: every nonce processed exactly once
    served = sorted(n for _, nonces in batches for n in nonces)
    assert served == sorted(tickets)
    # accounting closure: reasons and per-worker counters both sum to the
    # number of batches actually processed
    m = b.metrics
    assert sum(m.batches_by_reason.values()) == len(batches)
    assert sum(m.worker_batches.values()) == len(batches)
    assert m.requests_served == total and m.requests_failed == 0
    assert m.worker_errors == 0
    assert sum(m.occupancy_hist[k] * k for k in m.occupancy_hist) == total
    assert _batcher_threads() == before, "leaked worker threads"


@pytest.mark.parametrize("n_workers", WORKER_COUNTS)
def test_stress_clean_shutdown_with_full_queue(n_workers):
    """stop(drain=True) while the queue is still loaded: the in-flight
    batches finish, the rest is drained inline, nothing is lost and no
    worker thread survives. The 25th ticket can only be served by the
    drain path (max_wait is effectively infinite), proving shutdown
    flushes partial buckets."""
    release = threading.Event()
    started = threading.Event()

    def process(key, payloads):
        started.set()
        assert release.wait(30.0), "test forgot to release the workers"
        return [p for p in payloads]

    before = _batcher_threads()
    b = MicroBatcher(process, max_batch_size=2, max_wait_s=60.0,
                     n_workers=n_workers).start()
    tickets = b.submit_many(range(25))
    assert started.wait(30.0)         # workers are now blocked mid-batch
    assert b.depth > 0, "queue should still be loaded at shutdown"
    release.set()
    b.stop(drain=True)
    assert [t.result(timeout=0) for t in tickets] == list(range(25))
    m = b.metrics
    assert m.requests_served == 25
    assert not b.running
    assert m.batches_by_reason.get("drain", 0) >= 1   # the odd one out
    assert sum(m.batches_by_reason.values()) == \
        sum(m.worker_batches.values())
    assert _batcher_threads() == before, "leaked worker threads"


def test_rapid_start_stop_cycles_never_lose_tickets():
    """Repeated start/submit/stop cycles: every submission is served, and
    a post-stop submission fails fast instead of blocking forever."""
    def process(key, payloads):
        return [p for p in payloads]

    b = MicroBatcher(process, max_batch_size=4, max_wait_s=0.0, n_workers=2)
    for cycle in range(10):
        b.start()
        ts = b.submit_many(range(8))
        b.stop(drain=True)
        assert [t.result(timeout=10.0) for t in ts] == list(range(8))
        with pytest.raises(RuntimeError, match="stopped"):
            b.submit(99)


# ---------------------------------------------------------------------------
# fault isolation: poison payloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_workers", (1, 4))
def test_poison_payload_fails_only_its_ticket(n_workers):
    def process(key, payloads):
        if any(p == "poison" for p in payloads):
            raise ValueError("poisoned batch")
        return [str(p).upper() for p in payloads]

    b = MicroBatcher(process, max_batch_size=4, max_wait_s=60.0,
                     n_workers=n_workers).start()
    tickets = b.submit_many(["a", "poison", "b", "c"])  # one size-4 batch
    # healthy neighbours are served via the per-payload isolation retry
    assert tickets[0].result(timeout=30.0) == "A"
    assert tickets[2].result(timeout=30.0) == "B"
    assert tickets[3].result(timeout=30.0) == "C"
    with pytest.raises(ValueError, match="poisoned"):
        tickets[1].result(timeout=30.0)
    assert b.metrics.worker_errors == 1
    assert b.metrics.requests_failed == 1
    assert b.metrics.requests_served == 3

    # the worker loop survived: later submissions are still served by the
    # background workers (not the stop-drain path)
    after = b.submit_many(["x", "y", "z", "w"])
    assert [t.result(timeout=30.0) for t in after] == ["X", "Y", "Z", "W"]
    assert b.metrics.requests_served == 7
    b.stop()


def test_poison_flood_keeps_workers_alive():
    """Many poison payloads across many batches: every healthy ticket is
    served, every poison ticket carries its own error, errors are counted
    per isolation, and the workers survive the whole flood."""
    def process(key, payloads):
        if any(p % 7 == 3 for p in payloads):
            raise RuntimeError("boom")
        return [p * 10 for p in payloads]

    b = MicroBatcher(process, max_batch_size=4, max_wait_s=1e-4,
                     n_workers=4).start()
    tickets = b.submit_many(range(64))
    poisoned = {p for p in range(64) if p % 7 == 3}
    for p, t in enumerate(tickets):
        if p in poisoned:
            with pytest.raises(RuntimeError, match="boom"):
                t.result(timeout=30.0)
        else:
            assert t.result(timeout=30.0) == p * 10
    b.stop()
    m = b.metrics
    assert m.requests_failed == len(poisoned)
    assert m.requests_served == 64 - len(poisoned)
    assert m.worker_errors == len(poisoned)


# ---------------------------------------------------------------------------
# EdgeDetectService: ragged shapes x producer threads x workers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_workers", WORKER_COUNTS)
def test_service_stress_ragged_shapes_bit_identical(n_workers):
    """Concurrent producers submitting mixed-shape images through a
    multi-worker service: every result matches the direct single-image
    pipeline bit-for-bit (so no cross-wiring can hide behind shapes)."""
    imgs = mixed_shape_batch(18, shapes=((8, 8), (13, 9), (16, 16)),
                             noise=2.0)
    svc = EdgeDetectService("exact", max_batch_size=4, max_wait_s=1e-3,
                            bucket_granularity=8, n_workers=n_workers)
    try:
        refs = [np.asarray(conv.edge_detect_batched(im[None],
                                                    svc.substrate))[0]
                for im in imgs]
        results = [None] * len(imgs)
        errors = []

        def produce(lo, hi):
            try:
                tickets = [(i, svc.submit(imgs[i])) for i in range(lo, hi)]
                for i, t in tickets:
                    results[i] = t.result(timeout=60.0)
            except BaseException as e:  # noqa: BLE001 - surfaced below
                errors.append(e)

        threads = [threading.Thread(target=produce, args=(lo, lo + 6))
                   for lo in range(0, 18, 6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        svc.close()
    assert not errors, errors
    for i, (out, ref) in enumerate(zip(results, refs)):
        assert out is not None and np.array_equal(out, ref), \
            f"image {i} diverged (shape {imgs[i].shape})"
    m = svc.metrics
    assert m.requests_served == len(imgs) and m.requests_failed == 0
    assert sum(m.batches_by_reason.values()) == \
        sum(m.worker_batches.values())
