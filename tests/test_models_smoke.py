"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common as cm
from repro.models import registry as reg

REDUCTIONS = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=0, attn_chunk=64, loss_chunk=64, remat=False,
)


def reduced(name: str, **extra) -> cm.ModelConfig:
    cfg = reg.get_config(name)
    over = dict(REDUCTIONS)
    if cfg.n_experts:
        over.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_interleave=min(
            cfg.moe_interleave, 2))
    if cfg.local_global_ratio:
        over.update(n_layers=cfg.local_global_ratio + 1, local_window=32)
    if cfg.family == "encdec":
        over.update(n_encoder_layers=2, n_frames=16)
    if cfg.family == "vlm":
        over.update(n_patches=8, n_kv_heads=1)
    if cfg.family == "zamba":
        over.update(n_layers=6, shared_attn_every=3, ssm_state=8, n_kv_heads=4)
    if cfg.family == "xlstm":
        over.update(n_layers=2, n_heads=2)
    over.update(extra)
    return reg.get_config(name, **over)


def tiny_batch(cfg: cm.ModelConfig, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)), cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_frames, cfg.d_model)), cfg.dtype)
    return batch


ARCHS = [a for a in reg.list_archs() if a != "edge-detect"]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(arch)
    bundle = reg.get_bundle(arch, **dataclasses.asdict(cfg) and {})
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))

    # one SGD step, loss stays finite
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 1e-3 * g.astype(p.dtype) if jnp.issubdtype(
            p.dtype, jnp.floating) else p, params, grads)
    loss2 = jax.jit(bundle.loss_fn)(new_params, batch)
    assert np.isfinite(float(loss2)), arch
    # gradients flow: at least half the leaves have nonzero grads
    leaves = [g for g in jax.tree_util.tree_leaves(grads)
              if jnp.issubdtype(g.dtype, jnp.floating)]
    nonzero = sum(float(jnp.abs(g).max()) > 0 for g in leaves)
    assert nonzero >= len(leaves) // 2, (arch, nonzero, len(leaves))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill(arch):
    cfg = reduced(arch)
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(1))
    batch = tiny_batch(cfg)
    logits = jax.jit(bundle.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduced(arch)
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(2))
    b, max_len = 2, 64
    state = bundle.init_decode_state(b, max_len)
    if cfg.family == "encdec":
        state["enc_out"] = jnp.zeros((b, cfg.n_frames, cfg.d_model), cfg.dtype)
    batch = {"token": jnp.zeros((b, 1), jnp.int32),
             "cache_len": jnp.asarray(3, jnp.int32)}
    logits, new_state = jax.jit(bundle.decode_step)(params, state, batch)
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # decode twice (state threading works)
    batch2 = {"token": jnp.ones((b, 1), jnp.int32),
              "cache_len": jnp.asarray(4, jnp.int32)}
    logits2, _ = jax.jit(bundle.decode_step)(params, new_state, batch2)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_param_counts_match_headline_sizes():
    """Full configs hit their advertised parameter counts (±20 %)."""
    expect = {
        "llama4-maverick-400b-a17b": 400e9,
        "kimi-k2-1t-a32b": 1000e9,
        "internlm2-20b": 20e9,
        "qwen1.5-32b": 32e9,
        "gemma3-27b": 27e9,
        "minitron-8b": 8e9,
        "paligemma-3b": 3e9,
        "xlstm-125m": 125e6,
        "whisper-large-v3": 1.5e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, n in expect.items():
        got = reg.get_config(arch).param_count()
        assert 0.6 * n < got < 1.55 * n, (arch, got, n)


def test_active_params_moe():
    k = reg.get_config("kimi-k2-1t-a32b")
    assert k.active_param_count() < 0.06 * k.param_count()
    l4 = reg.get_config("llama4-maverick-400b-a17b")
    assert l4.active_param_count() < 0.12 * l4.param_count()


def test_input_specs_all_cells():
    """Every (arch × shape) cell has well-defined input specs."""
    for arch in ARCHS:
        cfg = reg.get_config(arch)
        for sname, spec in reg.SHAPES.items():
            if sname == "long_500k" and arch not in reg.SUBQUADRATIC:
                continue
            specs = reg.input_specs(cfg, spec)
            assert all(hasattr(v, "shape") for v in specs.values()), (arch, sname)
