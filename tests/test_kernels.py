"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests."""
import numpy as np
import pytest
import jax.numpy as jnp

# property tests need hypothesis (`pip install .[test]`); degrade gracefully
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import metrics, multiplier as m
from repro.kernels.closed_form import approx_product_i32
from repro.kernels.approx_mul.ops import approx_mul
from repro.kernels.approx_mul.ref import approx_mul_ref
from repro.kernels.approx_matmul.ops import approx_matmul
from repro.kernels.approx_matmul.ref import approx_matmul_ref
from repro.kernels.fused_conv.ops import fused_conv2d
from repro.kernels.fused_conv.ref import laplacian_conv_ref

RNG = np.random.default_rng(1234)


def _rand(shape, lo=-128, hi=128, dtype=np.int32):
    return RNG.integers(lo, hi, shape).astype(dtype)


def test_closed_form_equals_core_exhaustive():
    a, b = metrics.operand_grid(8)
    ref = np.asarray(m.approx_multiply(a, b))
    got = np.asarray(approx_product_i32(a, b))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("shape", [(1, 1), (7, 5), (64, 128), (128, 257), (3, 1000), (513, 130)])
def test_approx_mul_shapes(shape):
    a, b = _rand(shape), _rand(shape)
    np.testing.assert_array_equal(np.asarray(approx_mul(a, b)), np.asarray(approx_mul_ref(a, b)))


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
def test_approx_mul_dtypes(dtype):
    a = _rand((33, 47), dtype=dtype)
    b = _rand((33, 47), dtype=dtype)
    np.testing.assert_array_equal(np.asarray(approx_mul(a, b)), np.asarray(approx_mul_ref(a, b)))


def test_approx_mul_3d_shape():
    a, b = _rand((4, 9, 31)), _rand((4, 9, 31))
    np.testing.assert_array_equal(np.asarray(approx_mul(a, b)), np.asarray(approx_mul_ref(a, b)))


@pytest.mark.parametrize(
    "mkn", [(1, 1, 1), (8, 16, 8), (17, 29, 23), (64, 128, 64), (130, 70, 129), (5, 300, 2)]
)
def test_approx_matmul_shapes(mkn):
    mm, kk, nn = mkn
    a, b = _rand((mm, kk)), _rand((kk, nn))
    got = np.asarray(approx_matmul(a, b))
    ref = np.asarray(approx_matmul_ref(a, b))
    np.testing.assert_array_equal(got, ref)


def test_approx_matmul_blocks():
    a, b = _rand((96, 96)), _rand((96, 96))
    ref = np.asarray(approx_matmul_ref(a, b))
    for bm, bn, bk in [(32, 32, 32), (96, 96, 96), (48, 128, 8)]:
        got = np.asarray(approx_matmul(a, b, block_m=bm, block_n=bn, block_k=bk))
        np.testing.assert_array_equal(got, ref, err_msg=f"{bm},{bn},{bk}")


@pytest.mark.parametrize("shape", [(3, 3), (8, 8), (45, 61), (64, 64), (65, 129)])
def test_fused_conv_laplacian_shapes(shape):
    """The fused conv kernel reproduces the absorbed laplacian_conv oracle."""
    img = _rand(shape, lo=0, hi=128)
    from repro.nn.conv import LAPLACIAN

    got = np.asarray(fused_conv2d(img[None], LAPLACIAN, "proposed"))[0]
    np.testing.assert_array_equal(got, np.asarray(laplacian_conv_ref(img)))


def test_fused_conv_block_sizes():
    img = _rand((100, 40), lo=0, hi=128)
    from repro.nn.conv import LAPLACIAN

    ref = np.asarray(laplacian_conv_ref(img))
    for bh in (16, 25, 100):
        got = np.asarray(
            fused_conv2d(img[None], LAPLACIAN, "proposed", block_h=bh))[0]
        np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Property-based tests (hypothesis)
# ---------------------------------------------------------------------------

int8_val = st.integers(min_value=-128, max_value=127)


@settings(max_examples=50, deadline=None)
@given(a=int8_val, b=int8_val)
def test_property_closed_form_bounded_error(a, b):
    """|approx − exact| ≤ 769 + 128 + 256 (truncation + conversion + e1a)."""
    approx = int(approx_product_i32(jnp.int32(a), jnp.int32(b)))
    assert abs(approx - a * b) <= 769 + 128 + 256


@settings(max_examples=20, deadline=None)
@given(
    m_=st.integers(1, 24), k_=st.integers(1, 24), n_=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matmul_matches_oracle(m_, k_, n_, seed):
    r = np.random.default_rng(seed)
    a = r.integers(-128, 128, (m_, k_)).astype(np.int32)
    b = r.integers(-128, 128, (k_, n_)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(approx_matmul(a, b)), np.asarray(approx_matmul_ref(a, b))
    )


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_mul_commutativity_asymmetry(seed):
    """The multiplier is NOT symmetric (A-input is the negative pp) — but
    must still satisfy sign structure: f(a,b) stays within int16."""
    r = np.random.default_rng(seed)
    a = r.integers(-128, 128, (64,)).astype(np.int32)
    b = r.integers(-128, 128, (64,)).astype(np.int32)
    out = np.asarray(approx_mul(a, b))
    assert out.min() >= -(1 << 15) and out.max() < (1 << 15)
