"""LUT-input Pallas matmul kernel + width-parametric ``approx_pallas``.

Parity contract: the flat-table gather kernel (interpret mode on CPU) must
be bit-identical to ``approx_bitexact`` for every wiring in
``core.multiplier.WIRINGS`` — exhaustively over the N=4 operand grid (the
CI smoke gate, ``-k "exhaustive and n4"``), on ragged shapes that force
m/n/k padding, and end-to-end through the substrate registry and the
edge-detection service. Plus the satellite regressions: per-wiring f(0,0)
k-padding correction (the hard-coded 192 miscomputed any other wiring),
loud divisibility errors on the raw kernels, and strict spec parsing.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_lib
from repro.core import multiplier as mult
from repro.kernels.approx_matmul.kernel import approx_matmul_pallas
from repro.kernels.approx_matmul.ops import approx_matmul
from repro.kernels.lut_matmul.kernel import lut_matmul_pallas, table_width
from repro.kernels.lut_matmul.ops import lut_matmul
from repro.kernels.lut_matmul.ref import lut_matmul_ref
from repro.nn import substrate as sub

RNG = np.random.default_rng(41)

WIRING_NAMES = sorted(mult.WIRINGS)


def _pair_grid(n):
    """All width-n operand pairs as a (2^n, 1) @ (1, 2^n) K=1 matmul.

    K=1 also forces k-padding to the kernel's minimum block, so every
    exhaustive run exercises the f(0,0) correction too.
    """
    lo, hi = -(1 << (n - 1)), 1 << (n - 1)
    v = np.arange(lo, hi, dtype=np.int32)
    return v[:, None], v[None, :]


# ---------------------------------------------------------------------------
# kernel-level parity (CI smoke gate: -k "exhaustive and n4")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", WIRING_NAMES)
def test_lut_kernel_exhaustive_n4(name):
    """Every wiring, all 256 width-4 operand pairs through the kernel."""
    a, b = _pair_grid(4)
    flat = lut_lib.flat_lut(f"{name}@4")
    got = np.asarray(lut_matmul(a, b, flat))
    want = np.asarray(mult.make_multiplier(name, 4)(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want, err_msg=name)


def test_lut_kernel_exhaustive_n4_out_of_range_wraps():
    """Gather indices mask to N bits: out-of-range ints hit the same
    entries the closed form's operand wraparound computes."""
    flat = lut_lib.flat_lut("proposed@4")
    a = np.array([[8, 200, -9, 7]], np.int32).T   # wrap to -8, -8, 7, 7
    b = np.array([[3, -128, 127, 0]], np.int32)
    got = np.asarray(lut_matmul(a, b, flat))
    want = np.asarray(mult.make_multiplier("proposed", 4)(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mkn", [
    (1, 1, 1),          # degenerate
    (17, 33, 9),        # every dim off the block grid (matches approx_matmul suite)
    (5, 19, 3),
    (8, 128, 4),        # K exactly one block
])
@pytest.mark.parametrize("key", ["proposed", "design_strollo2020@4"])
def test_lut_kernel_ragged_shapes(mkn, key):
    m, k, n = mkn
    a = RNG.integers(-128, 128, (m, k)).astype(np.int32)
    b = RNG.integers(-128, 128, (k, n)).astype(np.int32)
    flat = lut_lib.flat_lut(key)
    got = np.asarray(lut_matmul(a, b, flat))
    ref = np.asarray(lut_matmul_ref(a, b, flat))
    np.testing.assert_array_equal(got, ref, err_msg=f"{key} {mkn}")


def test_lut_kernel_block_sizes():
    a = RNG.integers(-128, 128, (96, 96)).astype(np.int32)
    b = RNG.integers(-128, 128, (96, 96)).astype(np.int32)
    flat = lut_lib.flat_lut("proposed")
    ref = np.asarray(lut_matmul_ref(a, b, flat))
    for bm, bn, bk in [(32, 32, 32), (96, 96, 96), (48, 128, 8)]:
        got = np.asarray(lut_matmul(a, b, flat,
                                    block_m=bm, block_n=bn, block_k=bk))
        np.testing.assert_array_equal(got, ref, err_msg=f"{bm},{bn},{bk}")


def test_flat_lut_layout_matches_square_table():
    """flat[(a+off)<<n | (b+off)] must equal table[a+off, b+off]."""
    for key in ("proposed@4", "design_strollo2020"):
        table = lut_lib.build_lut(key)
        flat = lut_lib.flat_lut(key)
        n = table_width(flat.shape[0])
        assert table.shape == (1 << n, 1 << n)
        np.testing.assert_array_equal(flat.reshape(table.shape), table)


# ---------------------------------------------------------------------------
# per-wiring f(0,0) k-padding correction (regression: hard-coded 192)
# ---------------------------------------------------------------------------


def test_f00_shared_lookup_values():
    assert lut_lib.f00("proposed") == 192          # the paper's constant
    assert lut_lib.f00("proposed@4") == 4
    assert lut_lib.f00("design_strollo2020") == 64  # ≠ 192: the latent bug
    assert lut_lib.f00("design_strollo2020@4") == -4
    assert lut_lib.f00("exact") == 0


def test_kpad_correction_is_per_wiring_regression():
    """Contraction with k % block_k != 0 through a wiring whose f(0,0)
    differs from the proposed 192 — a hard-coded correction miscomputes
    every output element by (f00_wiring - 192) · pad."""
    key = "design_strollo2020"
    assert lut_lib.f00(key) != lut_lib.f00("proposed")
    m, k, n = 4, 3, 2                    # k=3 pads to the min block of 8
    a = RNG.integers(-128, 128, (m, k)).astype(np.int8)
    b = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    got = np.asarray(sub.get_substrate(f"approx_pallas:{key}").dot_int8(a, b))
    want = np.asarray(
        sub.get_substrate(f"approx_bitexact:{key}").dot_int8(a, b))
    np.testing.assert_array_equal(got, want)


def test_approx_matmul_kpad_correction_still_proposed():
    """The closed-form wrapper's correction now reads from the shared
    table lookup; proposed parity on k-padded shapes must be unchanged."""
    a = RNG.integers(-128, 128, (4, 3)).astype(np.int32)
    b = RNG.integers(-128, 128, (3, 2)).astype(np.int32)
    got = np.asarray(approx_matmul(a, b))
    want = np.asarray(mult.approx_multiply(
        a[:, :, None], b[None, :, :])).sum(axis=1)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# loud divisibility errors on the raw kernels
# ---------------------------------------------------------------------------


def test_approx_matmul_pallas_rejects_non_block_multiple():
    a = np.zeros((100, 128), np.int32)
    b = np.zeros((128, 128), np.int32)
    with pytest.raises(ValueError, match="multiple of .* block size"):
        approx_matmul_pallas(a, b, interpret=True)


def test_lut_matmul_pallas_rejects_non_block_multiple():
    flat = jnp.asarray(lut_lib.flat_lut("proposed"))
    a = np.zeros((128, 100), np.int32)
    b = np.zeros((100, 128), np.int32)
    with pytest.raises(ValueError, match="multiple of .* block size"):
        lut_matmul_pallas(a, b, flat, interpret=True)


def test_pallas_kernels_reject_shape_mismatch():
    flat = jnp.asarray(lut_lib.flat_lut("proposed"))
    a = np.zeros((128, 128), np.int32)
    b = np.zeros((64, 128), np.int32)
    with pytest.raises(ValueError, match="contraction-dim mismatch"):
        approx_matmul_pallas(a, b, interpret=True)
    with pytest.raises(ValueError, match="contraction-dim mismatch"):
        lut_matmul_pallas(a, b, flat, interpret=True)


def test_lut_matmul_rejects_non_lut_table():
    with pytest.raises(ValueError, match="flat product-LUT"):
        table_width(100)


# ---------------------------------------------------------------------------
# substrate-level: approx_pallas ≡ approx_bitexact at every wiring/width
# ---------------------------------------------------------------------------


def test_pallas_substrate_every_wiring_width_constructs():
    for name in WIRING_NAMES:
        for n in range(mult.MIN_BITS, lut_lib.MAX_LUT_BITS + 1):
            s = sub.get_substrate(f"approx_pallas:{name}@{n}")
            assert s.meta.name == "approx_pallas"
            assert (s.meta.mult_name, s.meta.width) == (name, n)
            assert s.meta.bit_exact and s.meta.scalar_faithful


def test_pallas_substrate_fast_path_vs_lut_path_metadata():
    # every CSP wiring/width gets the generated closed-form kernel ("vpu")
    assert sub.get_substrate("approx_pallas").meta.cost_hint == "vpu"
    assert sub.get_substrate(
        "approx_pallas:proposed@4").meta.cost_hint == "vpu"
    assert sub.get_substrate(
        "approx_pallas:design_du2022").meta.cost_hint == "vpu"
    # the LUT kernel remains as the non-CSP fallback and an explicit opt-in
    assert sub.get_substrate("approx_pallas:exact").meta.cost_hint == "gather"
    forced = sub.PallasSubstrate("design_du2022", kernel="lut")
    assert forced.meta.cost_hint == "gather"
    with pytest.raises(ValueError, match="unknown multiplier wiring"):
        sub.PallasSubstrate("exact", kernel="closed_form")


def test_pallas_substrate_rejects_unenumerable_width():
    with pytest.raises(ValueError, match="enumerable product table"):
        sub.get_substrate("approx_pallas:proposed@16")


@pytest.mark.parametrize("name", WIRING_NAMES)
def test_pallas_substrate_exhaustive_n4_matches_bitexact(name):
    """Acceptance: bit-identical to approx_bitexact on the exhaustive N=4
    grid (as a K=1 contraction, so the pad correction fires too)."""
    a, b = _pair_grid(4)
    got = np.asarray(
        sub.get_substrate(f"approx_pallas:{name}@4").dot_int8(a, b))
    want = np.asarray(
        sub.get_substrate(f"approx_bitexact:{name}@4").dot_int8(a, b))
    np.testing.assert_array_equal(got, want, err_msg=name)


@pytest.mark.parametrize("spec_suffix", ["design_du2022", "csp_axc1@4",
                                         "proposed@5"])
def test_pallas_substrate_sampled_matches_bitexact(spec_suffix):
    """Sampled parity incl. shapes that force k-padding, at N=8 and odd
    widths, through alias resolution."""
    ps = sub.get_substrate(f"approx_pallas:{spec_suffix}")
    bx = sub.get_substrate(f"approx_bitexact:{spec_suffix}")
    for m, k, n in [(5, 19, 3), (17, 33, 9)]:
        a = RNG.integers(-128, 128, (m, k)).astype(np.int8)
        b = RNG.integers(-128, 128, (k, n)).astype(np.int8)
        np.testing.assert_array_equal(
            np.asarray(ps.dot_int8(a, b)), np.asarray(bx.dot_int8(a, b)),
            err_msg=f"{spec_suffix} {(m, k, n)}")


def test_pallas_substrate_scalar_faithful_lut_path():
    """dot_int8 == Σ_k scalar(a_k, b_k) on the LUT path too."""
    s = sub.get_substrate("approx_pallas:design_strollo2020@4")
    a = RNG.integers(-8, 8, (4, 11)).astype(np.int8)
    b = RNG.integers(-8, 8, (11, 3)).astype(np.int8)
    oracle = np.asarray(s.scalar(jnp.asarray(a[:, :, None], jnp.int32),
                                 jnp.asarray(b[None, :, :], jnp.int32))
                        ).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(s.dot_int8(a, b)), oracle)


# ---------------------------------------------------------------------------
# strict spec parsing (bugfix: malformed specs used to parse as well-formed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    "exact:",                      # empty wiring suffix
    "approx_pallas:proposed@8 ",   # trailing whitespace
    " approx_lut",                 # leading whitespace
    "approx_lut :proposed",        # inner whitespace
    ":proposed",                   # empty backend
    "",                            # empty spec
    "approx_lut:@4",               # width without a wiring name
])
def test_malformed_specs_rejected(bad):
    with pytest.raises(ValueError, match="mult_name"):
        sub.parse_spec(bad)
    with pytest.raises(ValueError, match="mult_name"):
        sub.get_substrate(bad)


def test_empty_wiring_before_width_rejected_via_mult_name_arg():
    """'@4' alone must not silently fall back to the proposed wiring."""
    with pytest.raises(ValueError, match="mult_name"):
        sub.get_substrate("approx_bitexact", mult_name="@4")


def test_core_layer_rejects_malformed_width_and_empty_wiring():
    """The strictness holds at the core.multiplier layer too, not just the
    spec-string parser: int()'s whitespace/sign tolerance must not turn a
    typo into a well-formed key, and a bare '@N' must not silently default
    to the proposed wiring."""
    for bad in ("proposed@ 8", "proposed@+8", "proposed@-8", "proposed@",
                "proposed@８"):  # full-width '8': unicode digit, not ASCII
        with pytest.raises(ValueError, match="bad width suffix"):
            mult.split_width(bad)
        with pytest.raises(ValueError):  # whitespace or width-suffix layer
            sub.get_substrate(f"approx_lut:{bad}")
    with pytest.raises(ValueError, match="wiring name"):
        mult.resolve_multiplier("@4")


def test_well_formed_specs_still_parse():
    assert sub.parse_spec("approx_pallas:csp_axc1@4") == \
        ("approx_pallas", "csp_axc1", 4)
    assert sub.parse_spec("exact") == ("exact", "proposed", 8)
    s = sub.get_substrate("approx_pallas:csp_axc1@4")
    assert s.meta.spec == "approx_pallas:csp_axc1@4"
    assert sub.get_substrate(s.meta.spec) is s
