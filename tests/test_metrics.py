"""Error-metric machinery + LUT consistency."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut, metrics, multiplier as m


def test_operand_grid_covers_space():
    a, b = metrics.operand_grid(8)
    assert a.shape == (65536,)
    assert int(a.min()) == -128 and int(a.max()) == 127


def test_exact_multiplier_has_zero_error():
    rep = metrics.evaluate(m.exact_multiply, "exact")
    assert rep.er == 0 and rep.med == 0 and rep.mred == 0


def test_report_row_formatting():
    rep = metrics.evaluate(m.exact_multiply, "exact")
    assert "exact" in rep.row() and "ER=" in rep.row()


def test_lut_matches_function_exhaustively():
    table = lut.build_lut("proposed")
    assert table.shape == (256, 256)
    a, b = metrics.operand_grid(8)
    direct = np.asarray(m.approx_multiply(a, b))
    via_lut = np.asarray(lut.lut_multiply(a, b, jnp.asarray(table)))
    np.testing.assert_array_equal(direct, via_lut)


def test_error_lut_and_moments():
    e = lut.error_lut("proposed")
    mom = lut.error_moments("proposed")
    assert abs(mom["mean"] - e.astype(np.float64).mean()) < 1e-9
    # mean error (bias) is small relative to max product
    assert abs(mom["mean"]) < 100
    assert mom["max_abs"] < 2048


def test_exact_lut_is_products():
    t = lut.build_lut("exact")
    v = np.arange(-128, 128, dtype=np.int64)
    np.testing.assert_array_equal(t, v[:, None] * v[None, :])


def test_all_multipliers_evaluate():
    reps = metrics.evaluate_all(
        {k: m.ALL_MULTIPLIERS[k] for k in ("proposed", "design_du2022")}
    )
    assert reps["proposed"].mred < reps["design_du2022"].mred * 1.1
