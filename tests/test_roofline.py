"""Roofline derivation: HLO collective parsing + term math."""
import pytest

from repro.launch import roofline


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[256,1024]{1,0} parameter(0)
  %ag = bf16[256,16384]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[1024,1024]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[64,1024]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = s8[2048,128]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (bf16[512,512]{1,0}, bf16[512,512]{1,0}) all-gather-start(%v), dimensions={0}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_bytes():
    stats = roofline.parse_collectives(HLO)
    assert stats.bytes_by_kind["all-gather"] == 256 * 16384 * 2 + 2 * 512 * 512 * 2
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 1024 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 1024 * 4
    assert stats.bytes_by_kind["all-to-all"] == 2048 * 128 * 1
    assert stats.bytes_by_kind["collective-permute"] == 8 * 128 * 2
    assert stats.count_by_kind["all-gather"] == 2  # incl. -start form


def test_parse_ignores_non_collectives():
    stats = roofline.parse_collectives("%dot = f32[4,4] dot(%a, %b)")
    assert stats.total_bytes == 0


def test_roofline_terms_and_bottleneck():
    rf = roofline.Roofline(
        flops_per_device=197e12,      # exactly 1 s of compute
        bytes_per_device=819e9 / 2,   # 0.5 s of HBM
        collective_bytes=50e9 / 4,    # 0.25 s of ICI
        n_devices=256,
        model_flops=197e12 * 256 * 0.5,
    )
    assert rf.t_compute == pytest.approx(1.0)
    assert rf.t_memory == pytest.approx(0.5)
    assert rf.t_collective == pytest.approx(0.25)
    assert rf.bottleneck == "compute"
    assert rf.useful_flops_ratio == pytest.approx(0.5)
    assert rf.roofline_fraction == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro.models import registry as reg
    cfg = reg.get_config("minitron-8b")
    tr = roofline.model_flops_for(cfg, reg.SHAPES["train_4k"], n_active=1e9)
    pf = roofline.model_flops_for(cfg, reg.SHAPES["prefill_32k"], n_active=1e9)
    dc = roofline.model_flops_for(cfg, reg.SHAPES["decode_32k"], n_active=1e9)
    assert tr == 6e9 * 256 * 4096
    assert pf == 2e9 * 32 * 32768
    assert dc == 2e9 * 128


def test_tensor_bytes_dtypes():
    assert roofline._tensor_bytes("bf16", "2,3") == 12
    assert roofline._tensor_bytes("f32", "10") == 40
    assert roofline._tensor_bytes("s8", "7,3") == 21
    assert roofline._tensor_bytes("pred", "4") == 4
