"""Infrastructure: checkpointing, fault-tolerant train loop, data pipeline.

(Formerly ``test_substrate.py`` — renamed so it no longer shadows the
ProductSubstrate suite in ``test_substrates.py``; its serving-engine cases
moved to ``test_serving.py`` with the rest of the serving coverage.)
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import list_steps
from repro.data import SyntheticLMStream
from repro.models import registry as reg
from repro.optim import adamw, warmup_cosine
from repro.optim.grad_utils import clip_by_global_norm, compress_int8, decompress_int8
from repro.train import TrainLoop, TrainLoopConfig
from tests.test_models_smoke import reduced, tiny_batch


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    out, step, _ = load_checkpoint(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_tmp_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed save: partial tmp dir without manifest
    os.makedirs(tmp_path / "step_0000000009.tmp")
    (tmp_path / "step_0000000009.tmp" / "arrays.npz").write_bytes(b"garbage")
    # and a renamed-but-manifestless dir
    os.makedirs(tmp_path / "step_0000000007")
    assert list_steps(str(tmp_path)) == [1]
    _, step, _ = load_checkpoint(str(tmp_path), t)
    assert step == 1


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert list_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(11, _tree())
    mgr.wait()
    assert mgr.latest_step() == 11


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"a": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_seekable():
    s1 = SyntheticLMStream(vocab=97, batch=4, seq_len=16, seed=3)
    batches = [s1.next() for _ in range(5)]
    s2 = SyntheticLMStream(vocab=97, batch=4, seq_len=16, seed=3)
    s2.seek(3)
    np.testing.assert_array_equal(s2.next()["tokens"], batches[3]["tokens"])


def test_data_host_sharding_disjoint():
    a = SyntheticLMStream(vocab=97, batch=8, seq_len=8, seed=0, host_id=0, n_hosts=2)
    b = SyntheticLMStream(vocab=97, batch=8, seq_len=8, seed=0, host_id=1, n_hosts=2)
    assert a.next()["tokens"].shape == (4, 8)
    assert not np.array_equal(a._batch_at(0)["tokens"], b._batch_at(0)["tokens"])


def test_data_labels_shifted():
    s = SyntheticLMStream(vocab=50, batch=2, seq_len=12, seed=1)
    b = s.next()
    # labels are next-token targets: structure holds for ~70% of positions
    structured = (b["tokens"].astype(np.int64) * s._a + s._c) % 50
    frac = (structured == b["labels"]).mean()
    assert frac > 0.4


def test_data_prefetch():
    s = SyntheticLMStream(vocab=31, batch=2, seq_len=8, seed=5)
    ref = [s._batch_at(i)["tokens"] for i in range(3)]
    s.seek(0)
    s.start_prefetch()
    try:
        got = [s.next_prefetched()["tokens"] for _ in range(3)]
    finally:
        s.stop()
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g)


# ---------------------------------------------------------------------------
# grad utils
# ---------------------------------------------------------------------------


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_int8_compression_roundtrip():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale, jnp.float32)
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# train loop: loss goes down, crash → restart resumes exactly
# ---------------------------------------------------------------------------


def _loop_setup(tmp_path, total_steps=12, fail_at=None, seed=0):
    cfg = reduced("minitron-8b", n_layers=1, d_model=32, d_ff=64, vocab=64,
                  n_heads=2, n_kv_heads=2)
    bundle = reg._BUILDERS[cfg.family](cfg)
    loop = TrainLoop(
        bundle.loss_fn, adamw(weight_decay=0.0),
        TrainLoopConfig(total_steps=total_steps, ckpt_every=4,
                        ckpt_dir=str(tmp_path / "ckpt"), lr=5e-3,
                        fail_at_step=fail_at, async_ckpt=False),
        lr_schedule=warmup_cosine(5e-3, 2, total_steps),
    )
    stream = SyntheticLMStream(vocab=64, batch=4, seq_len=16, seed=seed)
    init = lambda: bundle.init_params(jax.random.PRNGKey(7))
    return loop, stream, init


def test_train_loss_decreases(tmp_path):
    loop, stream, init = _loop_setup(tmp_path, total_steps=30)
    params, opt, start = loop.init_or_restore(init)
    loop.run(params, opt, stream, start)
    losses = loop.metrics["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3] + losses[-3:]


def test_crash_restart_equivalence(tmp_path):
    # uninterrupted run
    loop_a, stream_a, init = _loop_setup(tmp_path / "a", total_steps=12)
    pa, oa, sa = loop_a.init_or_restore(init)
    pa, oa, _ = loop_a.run(pa, oa, stream_a, sa)

    # crashed at step 10 (after the step-8 checkpoint), then restarted
    loop_b, stream_b, init_b = _loop_setup(tmp_path / "b", total_steps=12, fail_at=10)
    pb, ob, sb = loop_b.init_or_restore(init_b)
    with pytest.raises(RuntimeError, match="injected failure"):
        loop_b.run(pb, ob, stream_b, sb)

    loop_c, stream_c, init_c = _loop_setup(tmp_path / "b", total_steps=12)
    pc, oc, sc = loop_c.init_or_restore(init_c)
    assert sc == 8 and loop_c.metrics["resumed_from"] == 8
    pc, oc, _ = loop_c.run(pc, oc, stream_c, sc)

    for a, b in zip(jax.tree_util.tree_leaves(pa), jax.tree_util.tree_leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)


def test_grad_accum_matches_full_batch(tmp_path):
    cfg = reduced("minitron-8b", n_layers=1, d_model=32, d_ff=64, vocab=64,
                  n_heads=2, n_kv_heads=2)
    bundle = reg._BUILDERS[cfg.family](cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    stream = SyntheticLMStream(vocab=64, batch=8, seq_len=16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in stream.next().items()}

    def run(accum):
        loop = TrainLoop(bundle.loss_fn, adamw(weight_decay=0.0),
                         TrainLoopConfig(grad_accum=accum, total_steps=1,
                                         ckpt_dir="/tmp/unused_ga"))
        opt = loop.optimizer.init(params)
        loss, gnorm, p2, _ = loop._step_fn(params, opt, batch, jnp.float32(1e-3))
        return float(loss), p2

    l1, p1 = run(1)
    l2, p2 = run(2)
    assert l1 == pytest.approx(l2, rel=2e-3)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=3e-2, atol=3e-3)


# serving-engine coverage lives in tests/test_serving.py
