"""Autotuner smoke: greedy search beats the uniform baseline, bundles
round-trip, and loaded plans serve bit-identically.

The edge search fixture uses a deliberately tiny workload (2 images,
64x64, one wiring, three widths) that deterministically finds the
``conv.edge.center -> proposed@6`` move — a strict PDP win at better
exact-backend PSNR — in a few seconds. Serving comparisons assert exact
equality: a loaded plan rebuilds the *same* trace as the plan object it
was saved from, so there is no float-reassociation epsilon to allow for.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import load_plan_bundle, save_plan_bundle
from repro.data import image_batch
from repro.launch import autotune as at
from repro.models import registry as reg
from repro.nn import conv
from repro.nn import plan as splan
from repro.serving import EdgeDetectService, Request, ServingEngine

# ---------------------------------------------------------------------------
# unit: stat rewrite, rule editing, PDP pricing
# ---------------------------------------------------------------------------


def test_stat_spec_rewrites_only_approx_backends():
    assert at.stat_spec("approx_bitexact:proposed@6") == \
        "approx_stat:proposed@6"
    assert at.stat_spec("approx_lut:design_du2022") == \
        "approx_stat:design_du2022@8"
    assert at.stat_spec("approx_pallas") == "approx_stat:proposed@8"
    assert at.stat_spec("exact") == "exact"
    assert at.stat_spec("int8") == "int8"


def test_stat_plan_rewrites_default_and_rules():
    plan = splan.SubstratePlan(
        default="approx_bitexact:proposed@8",
        rules=(("a.*", "int8"), ("b.*", "approx_lut:design_du2022@7")))
    sp = at.stat_plan(plan)
    assert sp.default == "approx_stat:proposed@8"
    assert sp.rules == (("a.*", "int8"), ("b.*", "approx_stat:design_du2022@7"))


def test_with_rule_replaces_pattern_in_place():
    plan = splan.SubstratePlan(
        default="exact", rules=(("a.*", "int8"), ("b.*", "exact")))
    p2 = at.with_rule(plan, "a.*", "approx_bitexact:proposed@6")
    assert p2.rules == (("b.*", "exact"), ("a.*", "approx_bitexact:proposed@6"))
    assert p2.resolve("a.x") == "approx_bitexact:proposed@6"
    p3 = at.with_rule(plan, "c.*", "int8")
    assert p3.rules == plan.rules + (("c.*", "int8"),)


def test_plan_pdp_fj_prices_by_resolved_site():
    site_macs = {"conv.edge.center": 100, "conv.edge.ring": 800}
    uni = splan.SubstratePlan.uniform("approx_bitexact:proposed@8")
    mixed = at.with_rule(uni, "conv.edge.center",
                         "approx_bitexact:proposed@6")
    assert at.plan_pdp_fj(site_macs, mixed) < at.plan_pdp_fj(site_macs, uni)
    # pricing is per-site linear: narrowing only the small site saves less
    # than narrowing everything
    all6 = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    assert at.plan_pdp_fj(site_macs, all6) < at.plan_pdp_fj(site_macs, mixed)


# ---------------------------------------------------------------------------
# edge smoke: search finds a strict win; bundle round-trips into serving
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def edge_result():
    return at.autotune_edge(n_images=2, size=(64, 64),
                            wirings=("proposed",), widths=(6, 7, 8))


def test_edge_autotune_beats_uniform_baseline(edge_result):
    res = edge_result
    assert res["plan"].rules, "search accepted no moves"
    assert res["tuned"]["pdp_fj"] < res["baseline"]["pdp_fj"]
    assert res["tuned"]["psnr_db"] >= res["baseline"]["psnr_db"]
    # the validated plan in the result dict is the one the summary reports
    assert res["tuned"]["plan"] == res["plan"].to_dict()


def test_edge_bundle_round_trips_and_serves_bit_identical(
        edge_result, tmp_path):
    plan = edge_result["plan"]
    out = str(tmp_path / "bundle")
    save_plan_bundle(out, plan,
                     extra={"autotune": at._result_summary(edge_result)})
    loaded, params, extra = load_plan_bundle(out)
    assert loaded == plan and params is None
    assert extra["autotune"]["tuned"]["pdp_fj"] == \
        edge_result["tuned"]["pdp_fj"]

    imgs = image_batch(3, 32, 32, seed=7)
    direct = np.asarray(conv.edge_detect_planned(imgs, plan))
    with EdgeDetectService(loaded, max_batch_size=2,
                           max_wait_s=1e-3) as svc:
        served = np.stack(svc.detect(imgs))
    np.testing.assert_array_equal(served, direct)


def test_engine_serves_lm_plan_bundle_bit_identical(tmp_path):
    cfg = reg.get_config("minitron-8b", n_layers=2, d_model=32, d_ff=64,
                         vocab=64, n_heads=2, n_kv_heads=2, attn_chunk=16,
                         loss_chunk=16, remat=False)
    bundle = reg.build_bundle(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    plan = splan.SubstratePlan(
        default="exact", rules=(("layer.1.*", "int8"),))
    out = str(tmp_path / "bundle")
    save_plan_bundle(out, plan, params=params)
    loaded_plan, loaded_params, _ = load_plan_bundle(
        out, params_template=params)
    assert loaded_plan == plan

    def greedy(engine_bundle, engine_params, substrate=None):
        eng = ServingEngine(engine_bundle, engine_params, batch_size=2,
                            max_len=32, substrate=substrate)
        reqs = [Request(prompt=[1, 2, 3], max_tokens=4),
                Request(prompt=[4, 5], max_tokens=4)]
        eng.generate(reqs)
        return [r.output for r in reqs]

    got = greedy(bundle, loaded_params, substrate=loaded_plan)
    ref_bundle = reg.build_bundle(dataclasses.replace(cfg, dot_plan=plan))
    assert got == greedy(ref_bundle, params)
