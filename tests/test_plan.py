"""Substrate plans: site resolution, scan dispatch, legacy parity, bundles.

Bit-identity semantics tested here follow the trace structure:

* a uniform plan and the legacy ``dot_mode`` string build the *same* traced
  graph, so their outputs are compared bit-for-bit;
* the scanned dispatch path vs an unrolled python-loop oracle are
  *different* traces of the same float math — XLA reassociates the
  quantize/rescale arithmetic differently under ``lax.scan`` (measured
  ~1.5e-05 even for uniform plans with no ``lax.switch`` involved), so
  those comparisons use a tight ``allclose``;
* the edge pipeline is integer-domain with exact accumulation, so planned
  (tap-group) vs whole-kernel edge maps compare bit-for-bit.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common as cm
from repro.models import registry as reg
from repro.nn import conv
from repro.nn import plan as splan
from repro.nn import substrate as sub
from repro.obs.meter import ContractionMeter, telemetry_scope

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# resolution rules
# ---------------------------------------------------------------------------


def test_resolution_precedence_exact_beats_glob():
    p = splan.SubstratePlan(default="exact", rules=(
        ("layer.*", "int8"),
        ("layer.3.attn.wq", "approx_bitexact:proposed@6"),
    ))
    assert p.resolve("layer.3.attn.wq") == "approx_bitexact:proposed@6"
    assert p.resolve("layer.3.attn.wk") == "int8"


def test_resolution_most_literal_glob_wins_regardless_of_order():
    rules = [("layer.*", "int8"), ("layer.3.attn.*", "approx_lut:proposed")]
    for ordering in (rules, rules[::-1]):
        p = splan.SubstratePlan(default="exact", rules=tuple(ordering))
        assert p.resolve("layer.3.attn.wq") == "approx_lut:proposed"
        assert p.resolve("layer.1.ffn.wg") == "int8"


def test_resolution_tie_goes_to_later_rule():
    p = splan.SubstratePlan(default="exact", rules=(
        ("layer.1.*", "int8"),
        ("*.attn.wq", "approx_lut:proposed"),  # same literal count (9)
    ))
    assert splan._specificity("layer.1.*") == splan._specificity("*.attn.wq")
    assert p.resolve("layer.1.attn.wq") == "approx_lut:proposed"


def test_resolution_unknown_site_falls_back_to_default():
    p = splan.SubstratePlan(default="approx_bitexact:proposed@8",
                            rules=(("conv.edge.*", "int8"),))
    assert p.resolve("layer.0.ffn.wo") == "approx_bitexact:proposed@8"
    assert p.resolve(None) == "approx_bitexact:proposed@8"


def test_resolution_cache_isolated_per_plan():
    # the lru cache keys on the (plan, site) pair: two plans assigning the
    # same site differently never bleed into each other
    a = splan.SubstratePlan(default="exact", rules=(("x.y", "int8"),))
    b = splan.SubstratePlan(default="exact",
                            rules=(("x.y", "approx_lut:proposed"),))
    assert a.resolve("x.y") == "int8"
    assert b.resolve("x.y") == "approx_lut:proposed"
    assert a.resolve("x.y") == "int8"  # a's cache entry survived b's


def test_plan_validates_specs():
    with pytest.raises(ValueError, match="unknown substrate backend"):
        splan.SubstratePlan(default="no_such_backend")
    with pytest.raises(ValueError, match="unknown substrate backend"):
        splan.SubstratePlan(rules=(("a.b", "mystery:proposed"),))
    with pytest.raises(ValueError):
        splan.SubstratePlan(rules=(("", "exact"),))
    # wirings are validated by the backend factories at resolution time
    p = splan.SubstratePlan(rules=(("a.b", "approx_lut:mystery_wiring"),))
    with pytest.raises(Exception):
        p.substrate_for("a.b")


def test_plan_json_and_dict_round_trip(tmp_path):
    p = splan.SubstratePlan(default="approx_bitexact:proposed@8", rules=(
        ("conv.edge.center", "approx_bitexact:proposed@6"),
        ("layer.*.ffn.*", "int8"),
    ))
    assert splan.SubstratePlan.from_json(p.to_json()) == p
    assert splan.as_plan(p.to_dict()) == p
    path = tmp_path / "plan.json"
    splan.save_plan(str(path), p)
    assert splan.load_plan(str(path)) == p
    assert splan.load_plan(str(tmp_path)) == p  # dir → dir/plan.json
    with pytest.raises(ValueError, match="newer than supported"):
        splan.SubstratePlan.from_dict({"version": 99, "default": "exact"})


def test_as_plan_accepts_spec_string_and_rejects_junk():
    p = splan.as_plan("int8")
    assert p.is_uniform and p.default == "int8"
    assert splan.as_plan(p) is p
    with pytest.raises(TypeError):
        splan.as_plan(42)


# ---------------------------------------------------------------------------
# site scopes + dispatch
# ---------------------------------------------------------------------------


def test_site_scope_composes_and_rejects_wildcards():
    with splan.site_scope("layer.3", "attn"):
        idx, sites = splan.current_sites("wq")
        assert idx is None and sites == ("layer.3.attn.wq",)
    assert splan.current_sites("wq") == (None, ("wq",))
    with pytest.raises(ValueError):
        splan.site_scope("layer.*").__enter__()


def test_scan_site_scope_yields_per_repeat_candidates_and_rejects_nesting():
    names = ("layer.0", "layer.1")
    with splan.scan_site_scope(jnp.asarray(0), names):
        idx, sites = splan.current_sites("ffn.wg")
        assert idx is not None
        assert sites == ("layer.0.ffn.wg", "layer.1.ffn.wg")
        with pytest.raises(RuntimeError, match="nested"):
            splan.scan_site_scope(jnp.asarray(0), names).__enter__()


def test_dispatch_static_when_repeats_agree():
    p = splan.SubstratePlan(default="exact", rules=(("layer.*", "int8"),))
    with splan.scan_site_scope(jnp.asarray(1), ("layer.0", "layer.1")):
        d = splan.dispatch(p, "attn.wq")
    assert d.index is None and d.branch_of is None
    assert d.groups == (("int8", "layer.*.attn.wq"),)


def test_dispatch_switch_groups_when_repeats_differ():
    p = splan.SubstratePlan(default="exact", rules=(
        ("layer.1.*", "int8"), ("layer.3.*", "int8"),))
    names = tuple(f"layer.{i}" for i in range(4))
    with splan.scan_site_scope(jnp.asarray(2), names):
        d = splan.dispatch(p, "ffn.wo")
    assert d.index is not None
    assert d.branch_of == (0, 1, 0, 1)
    specs = dict(zip([s for s, _ in d.groups], [l for _, l in d.groups]))
    assert set(specs) == {"exact", "int8"}


# ---------------------------------------------------------------------------
# model integration: legacy parity, deprecation shim, scan dispatch
# ---------------------------------------------------------------------------


def _tiny_cfg(**overrides):
    return reg.get_config("minitron-8b", n_layers=2, d_model=32, d_ff=64,
                          vocab=64, n_heads=2, n_kv_heads=2, attn_chunk=16,
                          loss_chunk=16, remat=False, **overrides)


def _prefill_logits(cfg):
    bundle = reg.build_bundle(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(1, cfg.vocab, (2, 16)), jnp.int32)}
    return np.asarray(bundle.prefill(params, batch), np.float32)


@pytest.mark.parametrize("spec", ["exact", "approx_bitexact", "approx_lut"])
def test_uniform_plan_bit_identical_to_legacy_dot_mode(spec):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = _prefill_logits(_tiny_cfg(dot_mode=spec))
    planned = _prefill_logits(
        _tiny_cfg(dot_plan=splan.SubstratePlan.uniform(spec)))
    np.testing.assert_array_equal(legacy, planned)


def test_dot_mode_deprecation_warning_and_shim():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        plan = cm.substrate_plan(_tiny_cfg(dot_mode="int8"))
    assert plan == splan.SubstratePlan.uniform("int8")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    # exact (the default) and explicit dot_plan stay silent
    for cfg in (_tiny_cfg(), _tiny_cfg(dot_mode="int8", dot_plan="int8")):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            cm.substrate_plan(cfg)
        assert not w


def test_dot_plan_wins_over_dot_mode():
    plan = cm.substrate_plan(_tiny_cfg(dot_mode="int8", dot_plan="approx_lut"))
    assert plan.default == "approx_lut"


def test_mixed_plan_under_scan_matches_python_loop_oracle():
    """The lax.switch dispatch selects the right substrate per scanned layer.

    The oracle applies the same per-layer assignment through an unrolled
    loop; scan-vs-loop float reassociation bounds the comparison (see
    module docstring), while the *wrong*-substrate failure mode is orders
    of magnitude larger (approx vs exact differ at O(1) in the logits).
    """
    mixed = splan.SubstratePlan(default="exact", rules=(
        ("layer.1.*", "approx_bitexact:proposed@8"),))
    cfg = _tiny_cfg(dot_plan=mixed)
    bundle = reg.build_bundle(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(1, cfg.vocab, (2, 16)), jnp.int32)}
    planned = np.asarray(bundle.prefill(params, batch), np.float32)

    exact = _prefill_logits(_tiny_cfg(dot_plan="exact"))
    approx = _prefill_logits(
        _tiny_cfg(dot_plan="approx_bitexact:proposed@8"))
    # the mixed plan is its own thing: neither all-exact nor all-approx
    assert np.abs(planned - exact).max() > 1e-3
    assert np.abs(planned - approx).max() > 1e-3

    # unrolled oracle: layer 1 approx, layer 0 exact, via leaf site scopes
    x = np.asarray(RNG.normal(size=(2, 8, 32)), np.float32)
    w = np.asarray(RNG.normal(size=(2, 32, 32)), np.float32)
    cfg_m = dataclasses.replace(cfg, dot_plan=mixed)

    def scan_fwd(x0):
        names = ("layer.0", "layer.1")

        def body(c, xs):
            wi, i = xs
            with splan.scan_site_scope(i, names):
                return cm.dense(cfg_m, c, wi, site="proj"), None
        return jax.lax.scan(body, x0, (jnp.asarray(w), jnp.arange(2)))[0]

    def loop_fwd(x0):
        c = jnp.asarray(x0)
        for i in range(2):
            with splan.site_scope(f"layer.{i}"):
                c = cm.dense(cfg_m, c, jnp.asarray(w[i]), site="proj")
        return c

    a, b = np.asarray(scan_fwd(x)), np.asarray(loop_fwd(x))
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=0)


def test_registry_bundle_carries_plan_and_default_substrate():
    mixed = splan.SubstratePlan(default="int8",
                                rules=(("layer.0.*", "exact"),))
    bundle = reg.build_bundle(_tiny_cfg(dot_plan=mixed))
    assert bundle.plan == mixed
    assert bundle.substrate is sub.get_substrate("int8")


# ---------------------------------------------------------------------------
# planned edge detection + per-site telemetry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    "approx_bitexact:proposed@8", "approx_bitexact:proposed@6",
    "approx_lut:design_du2022", "int8", "exact",
])
def test_uniform_planned_edge_bit_identical_to_batched(spec):
    imgs = np.asarray(RNG.integers(0, 256, (3, 24, 20)), np.uint8)
    direct = np.asarray(conv.edge_detect_batched(imgs, spec))
    planned = np.asarray(conv.edge_detect_planned(
        imgs, splan.SubstratePlan.uniform(spec)))
    np.testing.assert_array_equal(direct, planned)


def test_mixed_planned_edge_differs_and_is_deterministic():
    imgs = np.asarray(RNG.integers(0, 256, (2, 24, 24)), np.uint8)
    mixed = splan.SubstratePlan(
        default="approx_bitexact:proposed@8",
        rules=(("conv.edge.center", "approx_bitexact:proposed@6"),))
    uniform = np.asarray(conv.edge_detect_planned(
        imgs, splan.SubstratePlan.uniform("approx_bitexact:proposed@8")))
    a = np.asarray(conv.edge_detect_planned(imgs, mixed))
    b = np.asarray(conv.edge_detect_planned(imgs, mixed))
    np.testing.assert_array_equal(a, b)
    assert (a != uniform).any()


def test_per_site_energy_visible_in_metrics_export():
    imgs = np.asarray(RNG.integers(0, 256, (2, 16, 16)), np.uint8)
    mixed = splan.SubstratePlan(
        default="approx_bitexact:proposed@8",
        rules=(("conv.edge.center", "approx_bitexact:proposed@6"),))
    meter = ContractionMeter()
    with telemetry_scope(meter):
        np.asarray(conv.edge_detect_planned(imgs, mixed))
    sites = meter.site_summary()
    assert set(conv.edge_tap_sites()) <= set(sites)
    center = sites["conv.edge.center"]
    ring = sites["conv.edge.ring"]
    assert center["specs"] == [
        sub.get_substrate("approx_bitexact:proposed@6").meta.spec]
    assert ring["specs"] == [
        sub.get_substrate("approx_bitexact:proposed@8").meta.spec]
    assert ring["macs"] == 8 * center["macs"]  # 8 ring taps vs 1 center tap
    assert center["energy_pdp_fj"] > 0 and ring["energy_pdp_fj"] > 0
    # and the labeled series survive into the registry export
    export = meter.registry.to_json()
    assert "conv.edge.center" in str(export)


def test_lm_site_labels_reach_meter_through_scan():
    cfg = _tiny_cfg(dot_plan="exact")
    bundle = reg.build_bundle(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(1, cfg.vocab, (2, 16)), jnp.int32)}
    meter = ContractionMeter()
    with telemetry_scope(meter):
        np.asarray(bundle.prefill(params, batch))
    sites = set(meter.site_summary())
    # scanned layers condense to a glob label; leaves stay distinguishable
    assert any(s.endswith("attn.wq") for s in sites), sites
    assert any(s.endswith("ffn.wg") for s in sites), sites
