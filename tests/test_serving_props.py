"""Hypothesis stateful property tests for the serving scheduling core.

Skip-guarded: ``hypothesis`` is an optional ``[test]`` extra — when it is
not installed this whole module skips (the deterministic equivalents live
in ``test_serving_stress.py``).

Two machines drive randomized operation interleavings:

* :class:`MicroBatcherMachine` — submit/flush/stop-restart against a live
  2-worker batcher. Invariants at teardown: *ticket completeness* (every
  ticket served exactly once, carrying its own nonce — no loss, no
  duplication, no cross-wiring) and *per-bucket shape homogeneity* (bucket
  keys are ragged image shapes; a batch never mixes shapes and never
  overfills).
* :class:`SlotSchedulerMachine` — submit/refill/release against the
  fixed-slot scheduler. Invariants on every step: occupancy bounded by the
  slot count, no request seated twice, FIFO seating order preserved.
"""
import threading

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional [test] extra)")

from hypothesis import settings, strategies as st  # noqa: E402
from hypothesis.stateful import (RuleBasedStateMachine, initialize,  # noqa: E402
                                 invariant, rule)

from repro.serving import MicroBatcher, SlotScheduler  # noqa: E402

SHAPES = ((8, 8), (13, 9), (16, 16))


class MicroBatcherMachine(RuleBasedStateMachine):
    @initialize()
    def setup(self):
        self.lock = threading.Lock()
        self.batches = []             # (bucket_key, [nonce, ...]) per batch
        self.next_nonce = 0
        self.tickets = []

        def process(key, payloads):
            for shape, _nonce in payloads:
                assert shape == key, "batch mixed bucket shapes"
            with self.lock:
                self.batches.append((key, [n for _, n in payloads]))
            return [(key, n) for _, n in payloads]

        # max_wait is effectively infinite: flushes happen on size, on
        # explicit flush(), or at drain — the machine owns all timing
        self.mb = MicroBatcher(process, max_batch_size=3, max_wait_s=60.0,
                               bucket_fn=lambda p: p[0],
                               n_workers=2).start()

    @rule(shape=st.sampled_from(SHAPES))
    def submit(self, shape):
        nonce = self.next_nonce
        self.next_nonce += 1
        self.tickets.append((shape, nonce, self.mb.submit((shape, nonce))))

    @rule()
    def flush(self):
        self.mb.flush()

    @rule()
    def stop_and_restart(self):
        self.mb.stop(drain=True)      # drains everything queued
        self.mb.start()

    def teardown(self):
        self.mb.stop(drain=True)
        # ticket completeness + wiring: every ticket gets its own nonce
        for shape, nonce, t in self.tickets:
            assert t.result(timeout=30.0) == (shape, nonce)
        # exactly-once processing across all batches
        served = sorted(n for _, nonces in self.batches for n in nonces)
        assert served == list(range(self.next_nonce))
        # shape homogeneity + size bound for every flushed batch
        for key, nonces in self.batches:
            assert key in SHAPES and 1 <= len(nonces) <= 3


MicroBatcherMachine.TestCase.settings = settings(
    max_examples=25, stateful_step_count=30, deadline=None)
TestMicroBatcherStateful = MicroBatcherMachine.TestCase


class SlotSchedulerMachine(RuleBasedStateMachine):
    @initialize(n_slots=st.integers(min_value=1, max_value=4))
    def setup(self, n_slots):
        self.s = SlotScheduler(n_slots)
        self.submitted = 0
        self.seated_order = []

    @rule()
    def submit(self):
        self.s.submit(self.submitted)
        self.submitted += 1

    @rule()
    def refill(self):
        for _idx, item in self.s.refill():
            self.seated_order.append(item)

    @rule(data=st.data())
    def release_one(self, data):
        occupied = self.s.occupied()
        if occupied:
            idx, _item = data.draw(st.sampled_from(occupied))
            self.s.release(idx)

    @invariant()
    def occupancy_bounded(self):
        assert 0 <= self.s.occupancy <= self.s.n_slots

    @invariant()
    def seating_is_fifo_exactly_once(self):
        # requests are seated at most once, in submission order
        assert self.seated_order == sorted(set(self.seated_order))

    @invariant()
    def conservation(self):
        # everything submitted is queued, seated at some point, or gone
        # through a slot; nothing is duplicated between queue and history
        assert len(self.seated_order) + len(self.s.queue) == self.submitted


SlotSchedulerMachine.TestCase.settings = settings(
    max_examples=50, stateful_step_count=30, deadline=None)
TestSlotSchedulerStateful = SlotSchedulerMachine.TestCase
