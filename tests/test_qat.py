"""Approximation-aware training: STE semantics, scopes, recovery, restart.

Gradient identities tested here follow the STE contract
(:mod:`repro.train.qat`): the backward of the wrapped contraction is the
VJP of the *float* product under the same dimension numbers — so it must
match ``jax.grad`` through a plain float ``dot_general`` bit-for-bit (same
op, same trace), while the forward stays bit-identical to the approximate
substrate's own integer path. Crash→restart equivalence under QAT is
asserted *bitwise*: one process, deterministic CPU math, exact float32
checkpoint round-trip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLMStream
from repro.models import common as cm
from repro.models import registry as reg
from repro.nn import conv
from repro.nn import plan as splan
from repro.nn import substrate as psub
from repro.obs.meter import ContractionMeter, telemetry_scope
from repro.optim import adamw
from repro.train import QATPolicy, TrainLoop, TrainLoopConfig, qat

RNG = np.random.default_rng(0)


def _ops(m=4, k=8, n=5):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    return x, w


def _cspec():
    return psub.ContractionSpec.matmul(quant=psub.QuantPolicy())


# ---------------------------------------------------------------------------
# STE: forward bitwise, backward == float VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["approx_bitexact:proposed@8",
                                  "approx_bitexact:design_du2022@6",
                                  "approx_lut:proposed@7",
                                  "approx_stat:proposed@8",
                                  "int8"])
def test_forward_bitwise_equals_substrate(spec):
    x, w = _ops()
    cs = _cspec()
    out = qat.qat_dot_general(x, w, spec, cs)
    ref = psub.get_substrate(spec).dot_general(x, w, cs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("spec", ["approx_bitexact:proposed@8", "int8"])
def test_backward_equals_float_vjp(spec):
    x, w = _ops()
    cs = _cspec()
    g = jnp.asarray(RNG.normal(size=(4, 5)), jnp.float32)

    def qat_loss(a, b):
        return (qat.qat_dot_general(a, b, spec, cs) * g).sum()

    def float_loss(a, b):
        return (jax.lax.dot_general(a, b, (((1,), (0,)), ((), ()))) * g).sum()

    dq = jax.grad(qat_loss, argnums=(0, 1))(x, w)
    df = jax.grad(float_loss, argnums=(0, 1))(x, w)
    for a, b in zip(dq, df):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_finite_difference_sanity_dense_layer():
    """STE gradient ≈ FD of the float surrogate, and it descends the QAT loss.

    The MSE residual runs through the *approximate* output while the float
    surrogate's runs through the exact product, so the comparison is bounded
    by the wiring's output error — a loose relative check; the exact
    backward identity is covered by ``test_backward_equals_float_vjp``.
    """
    x, w = _ops(3, 6, 4)
    cs = _cspec()
    target = jnp.asarray(RNG.normal(size=(3, 4)), jnp.float32)
    spec = "approx_bitexact:proposed@8"

    def qat_loss(wf):
        return jnp.mean((qat.qat_dot_general(x, wf, spec, cs) - target) ** 2)

    def float_loss(wf):
        return float(jnp.mean((x @ wf - target) ** 2))

    g = np.asarray(jax.grad(qat_loss)(w))
    eps = 1e-2
    for idx in [(0, 0), (2, 1), (5, 3)]:
        d = np.zeros(w.shape, np.float32)
        d[idx] = eps
        fd = (float_loss(w + d) - float_loss(w - d)) / (2 * eps)
        assert abs(g[idx] - fd) <= 0.35 * max(abs(fd), 0.05), (idx, g[idx], fd)

    # a small gradient step reduces the QAT loss itself
    l0 = float(qat_loss(w))
    l1 = float(qat_loss(w - 0.05 * jnp.asarray(g)))
    assert l1 < l0, (l0, l1)


def test_exact_spec_passes_through_natively():
    x, w = _ops()
    cs = _cspec()
    out = qat.qat_dot_general(x, w, "exact", cs)
    ref = psub.get_substrate("exact").dot_general(x, w, cs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    g = jax.grad(lambda a: (qat.qat_dot_general(a, w, "exact", cs) ** 2).sum())(x)
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).max()) > 0


def test_quantless_contraction_rejected():
    x, w = _ops()
    cs = psub.ContractionSpec.matmul()  # no QuantPolicy
    with pytest.raises(ValueError, match="QuantPolicy"):
        qat.qat_dot_general(x, w, "approx_bitexact:proposed@8", cs)


def test_policy_validation_and_stat_rewrite():
    with pytest.raises(ValueError, match="forward"):
        QATPolicy(forward="nope")
    pol = QATPolicy(forward="stat")
    assert pol.forward_spec("approx_bitexact:proposed@6") == \
        "approx_stat:proposed@6"
    assert pol.forward_spec("exact") == "exact"
    assert QATPolicy.from_dict(pol.describe()) == pol


def test_moment_correction_changes_approx_grads():
    x, w = _ops()
    cs = _cspec()
    spec = "approx_bitexact:proposed@6"

    def loss(pol):
        return jax.grad(lambda a, b: (qat.qat_dot_general(
            a, b, spec, cs, pol) ** 2).sum(), argnums=(0, 1))(x, w)

    plain = loss(QATPolicy())
    corrected = loss(QATPolicy(moment_correction=True))
    for p, c in zip(plain, corrected):
        assert np.isfinite(np.asarray(c)).all()
    # the slope terms actually contribute for a biased wiring
    assert any(float(jnp.abs(p - c).max()) > 0 for p, c in zip(plain, corrected))


# ---------------------------------------------------------------------------
# qat_scope: plan composition, scan parity, value identity
# ---------------------------------------------------------------------------


def _tiny_cfg(**kw):
    return reg.get_config("minitron-8b", n_layers=2, d_model=32, d_ff=64,
                          vocab=64, n_heads=2, n_kv_heads=2, **kw)


def test_plan_override_scope_governs_dense_numerics():
    """The ambient plan override changes what dense() actually contracts.

    This is the mechanism behind checkpoint plan adoption: the train loop
    cannot rebuild an already-built loss_fn, so the adopted plan must win
    over the model config's at trace time.
    """
    cfg = _tiny_cfg()  # no dot_plan → exact numerics
    plan = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    x = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 32)), jnp.float32)
    exact = cm.dense(cfg, x, w, site="proj")
    with splan.plan_override_scope(plan):
        overridden = cm.dense(cfg, x, w, site="proj")
    assert splan.current_plan_override() is None  # scope restored
    planned = cm.dense(_tiny_cfg(dot_plan=plan), x, w, site="proj")
    np.testing.assert_array_equal(np.asarray(overridden), np.asarray(planned))
    assert float(jnp.abs(overridden - exact).max()) > 0


def test_qat_scope_forward_values_match_unscoped_dense():
    """The scope changes gradients, never values (STE fwd = substrate fwd)."""
    mixed = splan.SubstratePlan(default="approx_bitexact:proposed@8", rules=(
        ("layer.1.*", "approx_bitexact:design_du2022@6"),))
    cfg = _tiny_cfg(dot_plan=mixed)
    x = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 32)), jnp.float32)
    with splan.site_scope("layer.1"):
        ref = cm.dense(cfg, x, w, site="proj")
        with qat.qat_scope(QATPolicy()):
            out = cm.dense(cfg, x, w, site="proj")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_qat_under_scan_matches_python_loop_oracle():
    """Per-layer plans keep dispatching correctly inside lax.scan under QAT.

    Mirrors ``test_plan.py``'s scan-vs-loop oracle, but through the STE
    wrapper and for *gradients* as well as values (scan-vs-loop float
    reassociation bounds both comparisons).
    """
    mixed = splan.SubstratePlan(default="exact", rules=(
        ("layer.1.*", "approx_bitexact:proposed@8"),))
    cfg = _tiny_cfg(dot_plan=mixed)
    x = np.asarray(RNG.normal(size=(2, 8, 32)), np.float32)
    w = np.asarray(RNG.normal(size=(2, 32, 32)), np.float32)
    names = ("layer.0", "layer.1")

    def scan_fwd(x0, ws):
        def body(c, xs):
            wi, i = xs
            with splan.scan_site_scope(i, names):
                return cm.dense(cfg, c, wi, site="proj"), None
        return jax.lax.scan(body, x0, (ws, jnp.arange(2)))[0]

    def loop_fwd(x0, ws):
        c = x0
        for i in range(2):
            with splan.site_scope(f"layer.{i}"):
                c = cm.dense(cfg, c, ws[i], site="proj")
        return c

    def with_scope(fn):
        def wrapped(x0, ws):
            with qat.qat_scope(QATPolicy()):
                return (fn(x0, ws) ** 2).sum()
        return wrapped

    xs, ws = jnp.asarray(x), jnp.asarray(w)
    a = np.asarray(jax.jit(with_scope(scan_fwd))(xs, ws))
    b = np.asarray(jax.jit(with_scope(loop_fwd))(xs, ws))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)

    ga = jax.jit(jax.grad(with_scope(scan_fwd), argnums=(0, 1)))(xs, ws)
    gb = jax.jit(jax.grad(with_scope(loop_fwd), argnums=(0, 1)))(xs, ws)
    for u, v in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=1e-4, atol=1e-3)
    # the approximate layer's STE actually fires: grads are nonzero
    assert float(jnp.abs(ga[1][1]).max()) > 0


# ---------------------------------------------------------------------------
# telemetry: QAT forwards meter like any other contraction
# ---------------------------------------------------------------------------


def test_qat_training_step_meters_per_site_macs():
    imgs = jnp.asarray(RNG.integers(0, 256, size=(2, 12, 12)), jnp.uint8)
    plan = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    params = qat.init_edge_params()
    target = qat.edge_reference_response(imgs)

    def loss(p):
        return jnp.mean((qat.edge_response(p, imgs, plan) - target) ** 2)

    meter = ContractionMeter()
    with telemetry_scope(meter):
        jax.value_and_grad(loss)(params)
    sites = meter.site_summary()
    for site in conv.edge_tap_sites():
        assert site in sites and sites[site]["macs"] > 0, sites.keys()
        assert sites[site]["energy_pdp_fj"] > 0


def test_qat_forward_zero_meter_writes_without_scope():
    imgs = jnp.asarray(RNG.integers(0, 256, size=(2, 12, 12)), jnp.uint8)
    plan = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    bystander = ContractionMeter()
    qat.edge_response(qat.init_edge_params(), imgs, plan)  # no scope
    assert bystander.site_summary() == {}
    assert bystander.summary() == {}


# ---------------------------------------------------------------------------
# edge QAT model: init parity, width contract, recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [6, 8])
def test_edge_model_init_bitwise_matches_planned_pipeline(width):
    imgs = jnp.asarray(RNG.integers(0, 256, size=(3, 16, 16)), jnp.uint8)
    plan = splan.SubstratePlan.uniform(f"approx_bitexact:proposed@{width}")
    maps = qat.edge_maps(qat.init_edge_params(), imgs, plan)
    ref = conv.edge_detect_planned(imgs, plan)
    np.testing.assert_array_equal(np.asarray(maps), np.asarray(ref))


def test_edge_model_rejects_sub_clip_widths():
    imgs = jnp.asarray(RNG.integers(0, 256, size=(1, 8, 8)), jnp.uint8)
    plan = splan.SubstratePlan.uniform("approx_bitexact:proposed@4")
    with pytest.raises(ValueError, match="widths"):
        qat.edge_response(qat.init_edge_params(), imgs, plan)


def test_finetune_edge_recovers_cheap_wiring():
    from repro.data import image_batch

    imgs = jnp.asarray(image_batch(2, 24, 24, seed=3))
    plan = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    res = qat.finetune_edge(imgs, plan, steps=30, lr=0.05)
    # best-so-far params are kept, so the *best* loss is the training signal
    assert min(res["losses"]) < res["losses"][0]
    assert res["psnr_post"] >= res["psnr_pre"]


# ---------------------------------------------------------------------------
# TrainLoop integration: plan in manifests, bitwise crash→restart
# ---------------------------------------------------------------------------


_PLAN = splan.SubstratePlan.uniform("approx_stat:proposed@8")


def _qat_loop(tmp_path, total_steps=12, fail_at=None, plan=_PLAN,
              qat_policy=QATPolicy(forward="stat")):
    cfg = _tiny_cfg(dot_plan=plan) if plan is not None else _tiny_cfg()
    bundle = reg._BUILDERS[cfg.family](cfg)
    loop = TrainLoop(
        bundle.loss_fn, adamw(weight_decay=0.0),
        TrainLoopConfig(total_steps=total_steps, ckpt_every=4,
                        ckpt_dir=str(tmp_path / "ckpt"), lr=5e-3,
                        fail_at_step=fail_at, async_ckpt=False,
                        qat=qat_policy, plan=plan))
    stream = SyntheticLMStream(vocab=64, batch=4, seq_len=16, seed=0)
    init = lambda: bundle.init_params(jax.random.PRNGKey(7))
    return loop, stream, init


def test_qat_train_loss_decreases(tmp_path):
    loop, stream, init = _qat_loop(tmp_path, total_steps=25)
    params, opt, start = loop.init_or_restore(init)
    loop.run(params, opt, stream, start)
    losses = loop.metrics["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_qat_crash_restart_bitwise(tmp_path):
    loop_a, stream_a, init = _qat_loop(tmp_path / "a", total_steps=12)
    pa, oa, sa = loop_a.init_or_restore(init)
    pa, oa, _ = loop_a.run(pa, oa, stream_a, sa)

    loop_b, stream_b, init_b = _qat_loop(tmp_path / "b", total_steps=12,
                                         fail_at=10)
    pb, ob, sb = loop_b.init_or_restore(init_b)
    with pytest.raises(RuntimeError, match="injected failure"):
        loop_b.run(pb, ob, stream_b, sb)

    loop_c, stream_c, init_c = _qat_loop(tmp_path / "b", total_steps=12)
    pc, oc, sc = loop_c.init_or_restore(init_c)
    assert sc == 8 and loop_c.metrics["resumed_from"] == 8
    pc, oc, _ = loop_c.run(pc, oc, stream_c, sc)

    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manifest_records_plan_and_policy(tmp_path):
    loop, stream, init = _qat_loop(tmp_path, total_steps=4)
    params, opt, start = loop.init_or_restore(init)
    loop.run(params, opt, stream, start)
    _, _, extra = loop.ckpt.restore(
        {"params": params, "opt": opt})
    assert splan.SubstratePlan.from_dict(extra["plan"]) == _PLAN
    assert QATPolicy.from_dict(extra["qat"]) == QATPolicy(forward="stat")


def test_restore_adopts_plan_and_rejects_mismatch(tmp_path):
    loop, stream, init = _qat_loop(tmp_path, total_steps=4)
    params, opt, start = loop.init_or_restore(init)
    loop.run(params, opt, stream, start)

    # cfg.plan=None / cfg.qat=None adopt the checkpoint's plan AND policy
    # (a plan without the STE policy would train with zero grads through
    # the round() boundary)
    loop2, _, init2 = _qat_loop(tmp_path, total_steps=4, plan=None,
                                qat_policy=None)
    loop2.init_or_restore(init2)
    assert loop2.cfg.plan == _PLAN
    assert loop2.cfg.qat == QATPolicy(forward="stat")

    # a conflicting plan refuses to resume
    other = splan.SubstratePlan.uniform("approx_bitexact:proposed@6")
    loop3, _, init3 = _qat_loop(tmp_path, total_steps=4, plan=other)
    with pytest.raises(ValueError, match="plan"):
        loop3.init_or_restore(init3)


def test_adopted_plan_governs_resumed_contractions(tmp_path):
    """Adoption is effective, not cosmetic: a plan-less/policy-less resume
    continues *bitwise* identically to a resume that configures the
    checkpoint's plan + policy explicitly. The model bundle of the adopting
    run is built WITHOUT a dot_plan, so only the loop's trace-time override
    can be supplying the approximate numerics (and only the adopted STE
    policy can be supplying nonzero gradients through the quant boundary —
    bitwise-equal trained params prove both took effect)."""
    import shutil

    seed_loop, stream, init = _qat_loop(tmp_path / "a", total_steps=4)
    params, opt, start = seed_loop.init_or_restore(init)
    seed_loop.run(params, opt, stream, start)
    shutil.copytree(tmp_path / "a", tmp_path / "b")

    # explicit continuation: plan + policy passed in, as at seed time
    loop_e, stream_e, init_e = _qat_loop(tmp_path / "a", total_steps=8)
    pe, oe, se = loop_e.init_or_restore(init_e)
    pe, _, _ = loop_e.run(pe, oe, stream_e, se)

    # adopting continuation: nothing configured, everything from the manifest
    loop_a, stream_a, init_a = _qat_loop(tmp_path / "b", total_steps=8,
                                         plan=None, qat_policy=None)
    pa, oa, sa = loop_a.init_or_restore(init_a)
    assert sa == 4 and loop_a.cfg.plan == _PLAN
    pa, _, _ = loop_a.run(pa, oa, stream_a, sa)

    for a, b in zip(jax.tree_util.tree_leaves(pe),
                    jax.tree_util.tree_leaves(pa)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_parse_plan_arg_cli_forms(tmp_path):
    from repro.launch.train import parse_plan_arg

    assert parse_plan_arg("approx_bitexact:proposed@6").default == \
        "approx_bitexact:proposed@6"
    p = splan.SubstratePlan(default="exact",
                            rules=(("conv.edge.*", "approx_lut:proposed"),))
    assert parse_plan_arg(p.to_json()) == p
    path = tmp_path / "plan.json"
    splan.save_plan(str(path), p)
    assert parse_plan_arg(str(path)) == p
