"""Multiplier models: exact BW correctness, structural≡closed-form, Table 4."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, multiplier as m


@pytest.fixture(scope="module")
def grid():
    a, b = metrics.operand_grid(8)
    return np.asarray(a), np.asarray(b)


def test_exact_baugh_wooley_exhaustive(grid):
    """The BW PPM construction reproduces a*b on all 65 536 pairs."""
    a, b = grid
    got = np.asarray(jax.jit(m.exact_baugh_wooley)(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, a.astype(np.int64) * b.astype(np.int64))


def test_structural_equals_closed_form_exhaustive(grid):
    """Independent PPM/reduction-tree model == closed form on all pairs."""
    a, b = grid
    structural = np.asarray(jax.jit(m.StructuralMultiplier())(jnp.asarray(a), jnp.asarray(b)))
    closed = np.asarray(jax.jit(m.approx_multiply)(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(structural, closed)


def test_compensation_matches_expected_truncation():
    """2^7 + 2^6 = 192 ≈ E[T_T] = 192.25 (Eq. 5)."""
    assert m.compensation_constant(8) == 192
    assert abs(m.expected_truncation(8) - 192.25) < 1e-9


def test_truncated_sum_range(grid):
    a, b = grid
    t = np.asarray(jax.jit(m.truncated_sum)(jnp.asarray(a), jnp.asarray(b)))
    assert t.min() >= 0 and t.max() <= 769  # sum_q (q+1) 2^q, q=0..6


def test_output_is_in_2n_bit_range(grid):
    """Every registered model (incl. @4/@16 variants) stays in its own
    2n-bit two's-complement output range on width-matched operands."""
    a, b = grid
    for name, fn in m.ALL_MULTIPLIERS.items():
        _, n = m.split_width(name)
        aw = np.asarray(m.wrap_operand(jnp.asarray(a[::97]), n))
        bw = np.asarray(m.wrap_operand(jnp.asarray(b[::97]), n))
        out = np.asarray(jax.jit(fn)(jnp.asarray(aw), jnp.asarray(bw)))
        lo, hi = -(1 << (2 * n - 1)), (1 << (2 * n - 1))
        assert out.min() >= lo and out.max() < hi, name


def test_proposed_error_metrics_vs_table4():
    """Exhaustive ER/NMED/MRED land in the paper's Table-4 neighbourhood."""
    rep = metrics.evaluate(m.approx_multiply, "proposed")
    paper = metrics.PAPER_TABLE4["proposed"]
    # ER: the paper reports 98.04 %; every paper-consistent wiring we
    # enumerated lands at 99.8–100 % (exhaustive), so the paper's ER was
    # likely sampled — we accept a 2.5-point band and report ours.
    assert abs(rep.er * 100 - paper["er"]) < 2.5
    assert abs(rep.nmed * 100 - paper["nmed"]) < 0.05
    assert abs(rep.mred * 100 - paper["mred"]) < 1.0


def test_proposed_beats_du2022_on_nmed_and_mred():
    """Headline claim: proposed < best existing [2] on both error metrics."""
    prop = metrics.evaluate(m.approx_multiply, "proposed")
    du = metrics.evaluate(m.ALL_MULTIPLIERS["design_du2022"], "design_du2022")
    assert prop.mred <= du.mred * 1.05


def test_exact_csp_variant_is_truncation_only(grid):
    """With exact compressors the only error is truncation + compensation
    + the NAND→1 conversion (deterministic check on a sample)."""
    a, b = grid[0][:4096], grid[1][:4096]
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    got = np.asarray(jax.jit(m.ALL_MULTIPLIERS["trunc_exact_csp"])(aj, bj))
    t = np.asarray(m.truncated_sum(aj, bj))
    conv = ((a.astype(np.int64) >> 7) & 1) & (b.astype(np.int64) & 1)
    expect = a.astype(np.int64) * b.astype(np.int64) - t + 192 + (conv << 7)
    expect = np.where(expect >= 1 << 15, expect - (1 << 16), expect)
    np.testing.assert_array_equal(got, expect)


def test_wrap_int16():
    x = jnp.array([0, 32767, 32768, 65535, -1, 70000])
    got = np.asarray(m.wrap_int16(x))
    np.testing.assert_array_equal(got, [0, 32767, -32768, -1, -1, 4464])


@pytest.mark.parametrize("name", sorted(m.BASELINE_WIRINGS))
def test_baseline_multipliers_run_and_bounded(name, grid):
    a, b = grid
    fn = m.ALL_MULTIPLIERS[name]
    out = np.asarray(jax.jit(fn)(jnp.asarray(a[::31]), jnp.asarray(b[::31])))
    exact = a[::31].astype(np.int64) * b[::31].astype(np.int64)
    # bounded error: |err| < 2^11 (truncation ≤ 769 + few compressor LSBs)
    assert np.abs(out - exact).max() < 2048, name
