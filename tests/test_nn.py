"""NN layer: quantization, approx_dot execution modes, edge-detection conv."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut as lut_lib
from repro.nn import approx_dot as ad
from repro.nn import conv, quant

RNG = np.random.default_rng(7)


def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(RNG.normal(size=(64, 32)).astype(np.float32))
    q = quant.quantize(x)
    err = jnp.abs(q.dequantize() - x)
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_quantize_per_channel_scales():
    x = jnp.asarray(RNG.normal(size=(16, 4)).astype(np.float32)) * jnp.array([1, 10, 100, 1000.0])
    q = quant.quantize(x, axes=(0,))
    assert q.scale.shape == (1, 4)
    assert float(jnp.abs(q.dequantize() - x).max() / 1000) < 0.01


def test_quantized_values_in_range():
    x = jnp.asarray(RNG.normal(size=(128,)).astype(np.float32)) * 1e6
    q = quant.quantize(x)
    assert int(jnp.abs(q.values).max()) <= 127


def test_bitexact_equals_lut_mode():
    a8 = RNG.integers(-128, 128, (24, 40)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (40, 8)).astype(np.int8)
    bx = np.asarray(ad.approx_matmul_int8(a8, b8, mode="approx_bitexact"))
    lt = np.asarray(ad.approx_matmul_int8(a8, b8, mode="approx_lut"))
    np.testing.assert_array_equal(bx, lt)


def test_bitexact_matches_dense_oracle():
    a8 = RNG.integers(-128, 128, (9, 21)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (21, 5)).astype(np.int8)
    table = lut_lib.build_lut("proposed").astype(np.int64)
    oracle = table[a8.astype(np.int64)[:, :, None] + 128,
                   b8.astype(np.int64)[None, :, :] + 128].sum(axis=1)
    got = np.asarray(ad.approx_matmul_int8(a8, b8, mode="approx_bitexact"))
    np.testing.assert_array_equal(got, oracle)


def test_int8_mode_is_exact_integer_matmul():
    a8 = RNG.integers(-128, 128, (12, 33)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (33, 7)).astype(np.int8)
    got = np.asarray(ad.approx_matmul_int8(a8, b8, mode="int8"))
    np.testing.assert_array_equal(got, a8.astype(np.int64) @ b8.astype(np.int64))


def test_stat_mode_reduces_error_vs_uncorrected():
    """The separable error model must beat raw int8 at predicting the
    bit-exact approximate contraction (it models the multiplier's bias)."""
    a8 = RNG.integers(-128, 128, (32, 256)).astype(np.int8)
    b8 = RNG.integers(-128, 128, (256, 16)).astype(np.int8)
    bitexact = np.asarray(ad.approx_matmul_int8(a8, b8, mode="approx_bitexact"), np.int64)
    int8 = np.asarray(ad.approx_matmul_int8(a8, b8, mode="int8"), np.int64)
    stat = np.asarray(ad.approx_matmul_int8(a8, b8, mode="approx_stat"), np.int64)
    err_raw = np.abs(bitexact - int8).mean()
    err_stat = np.abs(bitexact - stat).mean()
    assert err_stat < err_raw * 0.8, (err_stat, err_raw)


@pytest.mark.parametrize("mode", ["exact", "int8", "approx_bitexact", "approx_lut", "approx_stat"])
def test_approx_dot_modes_close_to_float(mode):
    x = jnp.asarray(RNG.normal(size=(4, 6, 48)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(48, 24)).astype(np.float32))
    out = ad.approx_dot(x, w, mode=mode)
    ref = jnp.dot(x, w)
    assert out.shape == ref.shape
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    budget = {"exact": 1e-6, "int8": 0.05, "approx_bitexact": 0.2,
              "approx_lut": 0.2, "approx_stat": 0.2}[mode]
    assert rel < budget, (mode, rel)


def test_approx_dot_k_not_multiple_of_chunk():
    x = jnp.asarray(RNG.normal(size=(3, 19)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(19, 5)).astype(np.float32))
    out = ad.approx_dot(x, w, mode="approx_bitexact")
    assert out.shape == (3, 5) and bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# Edge-detection conv (paper §4)
# ---------------------------------------------------------------------------


def _test_image(h=64, w=64):
    """Procedural test image: gradients + rectangle + disk (strong edges)."""
    yy, xx = np.mgrid[0:h, 0:w]
    img = (xx * 255 / w).astype(np.float64)
    img[h // 4:h // 2, w // 4:w // 2] = 220
    img[(yy - 3 * h // 4) ** 2 + (xx - 3 * w // 4) ** 2 < (h // 6) ** 2] = 30
    return img.astype(np.uint8)


def test_edge_detect_runs_and_finds_edges():
    img = _test_image()
    edges = np.asarray(conv.edge_detect(img, "exact"))
    assert edges.dtype == np.uint8
    assert edges.max() > 50  # strong edges present


def test_edge_detect_proposed_psnr_vs_exact():
    """Paper Fig. 9 reports 20.13 dB on an unspecified image; PSNR is
    strongly image- and postprocessing-dependent (see EXPERIMENTS.md §Fig9),
    so we assert robust sanity bands: proposed > 8 dB, within 5 dB of the
    best framework-integrated design, and edge structure preserved
    (correlation with the exact edge map)."""
    img = _test_image(96, 96)
    ref = np.asarray(conv.edge_detect(img, "exact")).astype(np.float64)
    outs = {
        name: np.asarray(conv.edge_detect(img, name)).astype(np.float64)
        for name in ("proposed", "design_du2022", "design_strollo2020", "design_du2024")
    }
    psnrs = {n: conv.psnr(ref, o) for n, o in outs.items()}
    assert psnrs["proposed"] > 8.0, psnrs
    assert psnrs["proposed"] >= max(psnrs.values()) - 5.0, psnrs


def test_psnr_of_identical_images_is_inf():
    img = _test_image(16, 16)
    assert conv.psnr(img, img) == float("inf")


def test_conv2d_int_zero_kernel():
    img = _test_image(16, 16).astype(np.int32)
    from repro.core import multiplier as m
    out = conv.conv2d_int(jnp.asarray(img), jnp.zeros((3, 3), jnp.int32), m.exact_multiply)
    assert int(jnp.abs(out).max()) == 0
