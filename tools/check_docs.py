#!/usr/bin/env python
"""Docs link checker: every internal link and referenced repo path resolves.

Scans ``docs/*.md`` and ``README.md`` for

* markdown links ``[text](target)`` — relative targets must exist on disk
  (``#anchors`` within a file are stripped; http(s)/mailto links are
  skipped);
* inline-code repo paths like ```src/repro/core/multiplier.py`` or
  ``tools/check_docs.py`` — any backticked token that looks like a repo
  path (starts with a known top-level directory or is a root-level
  ``*.md``/``*.py``) must exist.

Exit code 0 when everything resolves, 1 with a per-file report otherwise.
Run from anywhere: paths resolve against the repo root (this file's
parent's parent).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_PREFIXES = ("src/", "docs/", "tools/", "tests/", "benchmarks/",
                 "examples/", ".github/")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def _doc_files() -> list[Path]:
    files = sorted((REPO / "docs").glob("*.md")) if (REPO / "docs").is_dir() else []
    readme = REPO / "README.md"
    if readme.exists():
        files.append(readme)
    return files


def _looks_like_repo_path(token: str) -> bool:
    if not re.fullmatch(r"[\w./\-]+", token):
        return False
    if token.startswith(PATH_PREFIXES):
        return True
    # root-level files like README.md / ROADMAP.md / pyproject.toml
    return "/" not in token and token.endswith((".md", ".toml")) \
        and token[0].isupper() or token == "pyproject.toml"


FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    prose = FENCE_RE.sub("", text)  # links only count outside code fences

    for target in LINK_RE.findall(prose):
        if target.startswith(SKIP_SCHEMES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:  # pure in-page anchor
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            errors.append(f"broken link: ({target})")

    for token in CODE_RE.findall(text):
        token = token.strip()
        if not _looks_like_repo_path(token):
            continue
        if not (REPO / token).exists():
            errors.append(f"missing repo path: `{token}`")
    return errors


def main() -> int:
    files = _doc_files()
    if not files:
        print("check_docs: no docs found", file=sys.stderr)
        return 1
    failed = False
    for f in files:
        errs = check_file(f)
        rel = f.relative_to(REPO)
        if errs:
            failed = True
            print(f"{rel}:")
            for e in errs:
                print(f"  {e}")
        else:
            print(f"{rel}: OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
